//! The service core: every transport (CLI, TCP serve, client examples)
//! routes typed [`Request`]s through one [`Service`].
//!
//! The service owns the shared immutable [`Config`] (`Arc`, so
//! connection threads scale across cores the way the paper's ACEs scale
//! independent streams) and the one non-`Sync` resource — the PJRT
//! executor — isolated on a single worker thread behind an mpsc channel.
//! `run` requests serialize through that worker (like launches through a
//! command lane) without ever blocking the simulator paths.
//!
//! Input validation is typed: out-of-range values produce
//! [`ErrorCode::BadRange`] errors naming the accepted range (DESIGN.md
//! §6.3) instead of the pre-API behavior of silently clamping stream
//! counts and answering a different question.
//!
//! ## Scenarios (DESIGN.md §6.6)
//!
//! Every simulator question is a [`ScenarioSpec`]: the v1
//! `sim`/`plan`/`sparsity` requests desugar into single-point specs, and
//! the `scenario` request runs a validated sweep through the scoped
//! pool, answering each [`Point`] exactly as the equivalent v1 request
//! would — byte-identically, because both run the same compiled path.
//!
//! ## Backends (DESIGN.md §6.8)
//!
//! Each point executes on a [`crate::backend::Backend`] from the
//! backend registry: `des` (discrete-event replay — the default, and
//! byte-identical to the pre-backend service), `analytic` (calibrated
//! closed forms, no DES stepping), or `auto` (the trust-region router,
//! DESIGN.md §6.10). Selection comes from the spec's `backend` field or
//! the request envelope's `"backend"` key, resolved and
//! capability-gated up front ([`ErrorCode::UnsupportedByBackend`]
//! before any point runs); the resolved backend is canonicalized into
//! the per-point cache key, so backends never share cache entries, and
//! cold executions are counted per backend for the `stats` request.
//! `auto` is resolved one step further, per point: the router's
//! concrete pick (analytic inside the measured trust region, DES
//! elsewhere) is what lands in the cache key and the counters, so
//! routed points share entries with explicit requests and
//! `engine_runs_auto` stays 0 by design. Budgeted `auto` *jobs*
//! additionally get a DES refinement pass over their
//! lowest-confidence analytic answers ([`refine_job`]), streamed as
//! `refined` progress frames.
//!
//! ## Caching
//!
//! The service embeds a [`ResultCache`] (see [`super::cache`]) keyed at
//! **sweep-point granularity**: each point memoizes under the canonical
//! wire form of its single-point spec ([`ScenarioSpec::at`]), so a v1
//! `sim` repeat, the same point inside a sweep, and a job's point all
//! share one entry. `repro` of deterministic registry entries stays
//! memoized under its request form. Repeats answer byte-identically
//! with zero DES re-execution, provable through `stats` whose
//! `engine_runs` counter only moves on cold executions (including the
//! `repro_all` driver sweep). [`Service::handle_opts`] with
//! `use_cache: false` (the wire `"cache":false` escape hatch) always
//! runs cold.
//!
//! ## Jobs (DESIGN.md §6.7)
//!
//! Long-running sweeps go through the bounded [`JobTable`]:
//! `submit` validates the spec synchronously, enqueues it (or answers
//! `overloaded`), and `max_running` worker threads execute jobs
//! point-by-point — honoring cancels between points and framing
//! per-point progress to watchers (the serve transport's `progress`
//! push).

use super::cache::{CachePolicy, CacheStats, ResultCache};
use super::job::{JobLimits, JobTable, JobView};
use super::protocol::{
    objective_name, ApiError, BackendInfo, ErrorCode, ExperimentInfo,
    PlanGroup, Request, RequestEnvelope, Response, MAX_BATCH_ITEMS,
};
use super::scenario::{Ask, Point, PointResult, ScenarioSpec, Sweep};
use crate::backend::auto::TrustTable;
use crate::backend::{self, BackendId};
use crate::config::Config;
use crate::experiments;
use crate::runtime::manifest::EntrySpec;
use crate::runtime::{Executor, Manifest};
use crate::util::pool;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Accepted `streams` range for `sim` requests (the DES models the
/// MI300A's hardware queues; beyond 16 the model is uncalibrated).
pub const SIM_STREAMS: (usize, usize) = (1, 16);
/// Accepted `streams` range for `plan` and `sparsity` requests.
pub const POOL_STREAMS: (usize, usize) = (1, 64);
/// Accepted GEMM size range for `sim`/`plan`/`sparsity` requests.
pub const SIZE_RANGE: (usize, usize) = (1, 16384);

/// A queued artifact execution: run `entry`, reply on `reply`.
struct ExecJob {
    entry: String,
    reply: mpsc::Sender<Result<RunOutcome, ApiError>>,
}

struct RunOutcome {
    entry: String,
    outputs: usize,
    checksum: f64,
    exec_ms: f64,
}

/// The execution state shared by connection threads and job workers:
/// config, result cache, counters, and the executor-worker channel.
struct Core {
    cfg: Arc<Config>,
    artifacts_dir: PathBuf,
    // The worker-channel sender lives behind a Mutex only to guarantee
    // `Sync` on every toolchain; senders are cloned out per request.
    exec_tx: Mutex<mpsc::Sender<ExecJob>>,
    cache: ResultCache,
    // Cold executions of a simulator/coordinator/driver path — the
    // engine-invocation counter `stats` reports. Cache hits never
    // touch it, which is what lets tests prove a repeat request did
    // zero re-execution.
    engine_runs: AtomicU64,
    // Cold scenario-point executions split per backend (DESIGN.md
    // §6.8): `engine_runs` stays the total (points + repro drivers),
    // so cache-bypass accounting stays truthful per backend too.
    backend_runs: [AtomicU64; backend::COUNT],
    // The backend answering requests that name none (`serve --backend`
    // overrides; `des` everywhere else, preserving pre-backend bytes).
    default_backend: BackendId,
}

/// The single front door to the system. `Send + Sync`: share it behind
/// an `Arc` across connection threads.
pub struct Service {
    core: Arc<Core>,
    jobs: Arc<JobTable>,
    job_workers: Vec<thread::JoinHandle<()>>,
}

impl Service {
    /// Service over the default artifacts directory and cache policy.
    pub fn new(cfg: Config) -> Service {
        Service::with_options(
            cfg,
            Manifest::default_dir(),
            CachePolicy::default(),
        )
    }

    /// Service executing artifacts from `artifacts_dir` (default cache
    /// policy).
    pub fn with_artifacts_dir(cfg: Config, artifacts_dir: PathBuf) -> Service {
        Service::with_options(cfg, artifacts_dir, CachePolicy::default())
    }

    /// Service with an explicit result-cache policy (the CLI's
    /// `--no-cache` builds one from [`CachePolicy::disabled`]).
    pub fn with_cache_policy(cfg: Config, policy: CachePolicy) -> Service {
        Service::with_options(cfg, Manifest::default_dir(), policy)
    }

    /// Service with explicit job-table limits (tests shrink the queue
    /// to exercise `overloaded` deterministically).
    pub fn with_job_limits(cfg: Config, limits: JobLimits) -> Service {
        Service::with_limits(
            cfg,
            Manifest::default_dir(),
            CachePolicy::default(),
            limits,
        )
    }

    /// Mostly-explicit constructor (default job limits).
    pub fn with_options(
        cfg: Config,
        artifacts_dir: PathBuf,
        policy: CachePolicy,
    ) -> Service {
        Service::with_limits(cfg, artifacts_dir, policy, JobLimits::default())
    }

    /// Service whose requests default to `default_backend` when they
    /// name none (the CLI's `serve --backend`; DESIGN.md §6.8).
    pub fn with_default_backend(
        cfg: Config,
        policy: CachePolicy,
        default_backend: BackendId,
    ) -> Service {
        Service::build(
            cfg,
            Manifest::default_dir(),
            policy,
            JobLimits::default(),
            default_backend,
        )
    }

    /// Fully-explicit constructor minus the backend default. Spawns the
    /// executor worker thread and `limits.max_running` job workers; all
    /// exit when the service is dropped.
    pub fn with_limits(
        cfg: Config,
        artifacts_dir: PathBuf,
        policy: CachePolicy,
        limits: JobLimits,
    ) -> Service {
        Service::build(cfg, artifacts_dir, policy, limits, backend::DEFAULT)
    }

    /// The one real constructor.
    fn build(
        cfg: Config,
        artifacts_dir: PathBuf,
        policy: CachePolicy,
        limits: JobLimits,
        default_backend: BackendId,
    ) -> Service {
        let (tx, rx) = mpsc::channel::<ExecJob>();
        let worker_dir = artifacts_dir.clone();
        thread::Builder::new()
            .name("api-exec-worker".into())
            .spawn(move || exec_worker(&worker_dir, rx))
            .expect("spawn executor worker");
        let core = Arc::new(Core {
            cfg: Arc::new(cfg),
            artifacts_dir,
            exec_tx: Mutex::new(tx),
            cache: ResultCache::new(policy),
            engine_runs: AtomicU64::new(0),
            backend_runs: std::array::from_fn(|_| AtomicU64::new(0)),
            default_backend,
        });
        let jobs = Arc::new(JobTable::new(limits));
        let job_workers = (0..limits.max_running)
            .map(|i| {
                let core = Arc::clone(&core);
                let jobs = Arc::clone(&jobs);
                thread::Builder::new()
                    .name(format!("api-job-worker-{i}"))
                    .spawn(move || job_worker(&core, &jobs))
                    .expect("spawn job worker")
            })
            .collect();
        Service { core, jobs, job_workers }
    }

    /// The active (immutable) configuration.
    pub fn config(&self) -> &Config {
        &self.core.cfg
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.core.artifacts_dir
    }

    /// Load the artifact manifest (introspection; no execution).
    pub fn load_manifest(&self) -> Result<Manifest, String> {
        Manifest::load(&self.core.artifacts_dir)
    }

    /// Handle one typed request through the result cache. Never panics
    /// on bad input: every failure is a typed [`Response::Error`].
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_env(req, &RequestEnvelope::default())
    }

    /// Handle one typed request with an explicit cache mode.
    /// `use_cache: false` is the `"cache":false` / `--no-cache` escape
    /// hatch: the request always runs cold and counts neither a hit
    /// nor a miss.
    pub fn handle_opts(&self, req: &Request, use_cache: bool) -> Response {
        self.handle_env(
            req,
            &RequestEnvelope { cache: use_cache, ..RequestEnvelope::default() },
        )
    }

    /// Handle one typed request with full envelope options (`cache`
    /// escape hatch + `backend` selector, DESIGN.md §6.8). A batch fans
    /// its items through the same path, so identical items within one
    /// batch share the cache; the envelope's backend applies to every
    /// scenario-backed item, and other items (e.g. a trailing `stats`)
    /// simply ignore it — so a measure-then-read-counters batch works
    /// under any selector. A *top-level* non-scenario request with a
    /// backend selector is still a typed error.
    pub fn handle_env(&self, req: &Request, env: &RequestEnvelope) -> Response {
        if let Request::Batch { items } = req {
            // Mirror the wire decoder's 1..=MAX_BATCH_ITEMS contract for
            // programmatically built batches too.
            if items.is_empty() {
                return Response::from(ApiError::bad_request(
                    "batch: \"items\" must not be empty",
                ));
            }
            if items.len() > MAX_BATCH_ITEMS {
                return Response::from(ApiError::new(
                    ErrorCode::BadRange,
                    format!(
                        "batch items must be in 1..={MAX_BATCH_ITEMS} \
                         (got {})",
                        items.len()
                    ),
                ));
            }
            return Response::Batch {
                items: items
                    .iter()
                    .map(|item| self.handle_one(item, env, false))
                    .collect(),
            };
        }
        self.handle_one(req, env, true)
    }

    /// Result-cache counters (the `stats` request's `cache_*` fields).
    pub fn cache_stats(&self) -> CacheStats {
        self.core.cache.stats()
    }

    /// Cold engine/driver executions so far (the `stats` request's
    /// `engine_runs` field).
    pub fn engine_runs(&self) -> u64 {
        self.core.engine_runs.load(Ordering::Relaxed)
    }

    /// Cold scenario-point executions per backend, in
    /// [`BackendId::ALL`] order (the `stats` request's
    /// `engine_runs_<backend>` fields). Sums to at most
    /// [`Service::engine_runs`] — repro drivers count only toward the
    /// total.
    pub fn backend_runs(&self) -> Vec<u64> {
        self.core
            .backend_runs
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// The backend answering requests that name none.
    pub fn default_backend(&self) -> BackendId {
        self.core.default_backend
    }

    /// One non-batch request. Scenario-backed requests (the v1
    /// simulator trio and `scenario` itself) resolve their backend and
    /// run point-by-point through the per-point cache; `repro` keeps
    /// request-level memoization; everything else runs cold. Error
    /// responses are never cached. `strict_backend` is false for batch
    /// items: a batch-envelope backend selector applies to the
    /// scenario-backed items and is ignored by the rest, while a
    /// top-level misplaced selector is a typed error.
    fn handle_one(
        &self,
        req: &Request,
        env: &RequestEnvelope,
        strict_backend: bool,
    ) -> Response {
        if let Some((spec, single)) = desugar(req) {
            let resolved = match self.resolved_spec(&spec, env.backend) {
                Ok(s) => s,
                Err(e) => return Response::from(e),
            };
            return match self.core.run_scenario(&resolved, env.cache) {
                Ok(resp) if single => unwrap_single(resp),
                Ok(resp) => resp,
                Err(e) => Response::from(e),
            };
        }
        // Submit carries the envelope's cache flag and backend into the
        // job, so a `"cache":false` or `"backend":"analytic"`
        // measurement sweep runs in the workers exactly like its
        // synchronous `scenario` form would.
        if let Request::Submit { spec, .. } = req {
            let resolved = match self.resolved_spec(spec, env.backend) {
                Ok(s) => s,
                Err(e) => return Response::from(e),
            };
            return match self.submit_resolved(resolved, false, env.cache) {
                Ok((view, _rx)) => Response::Job(view),
                Err(e) => Response::from(e),
            };
        }
        // A top-level backend selector on anything else is a typed
        // error, not a silent no-op (batch items are lenient — see
        // `strict_backend`).
        if strict_backend && env.backend.is_some() {
            return Response::from(ApiError::bad_request(format!(
                "\"backend\" only applies to sim/plan/sparsity/scenario/\
                 submit requests (got {:?})",
                req.type_name()
            )));
        }
        let cold = |r: &Request| match self.try_handle(r) {
            Ok(resp) => resp,
            Err(e) => Response::from(e),
        };
        if env.cache && self.cacheable(req) {
            let key = req.cache_key();
            if let Some(resp) = self.core.cache.get(&key) {
                return resp;
            }
            let resp = cold(req);
            if !matches!(resp, Response::Error { .. }) {
                self.core.cache.insert(key, &resp);
            }
            return resp;
        }
        cold(req)
    }

    /// Resolve a spec's execution backend (spec field, else envelope
    /// key, else the service default) and gate it on the backend's
    /// capabilities (DESIGN.md §6.8). Capability gating runs before
    /// range validation — all-or-nothing, so an unsupported sweep never
    /// half-answers. The resolved spec names its backend explicitly,
    /// which is what keys the per-point cache (backends never share
    /// entries).
    fn resolved_spec(
        &self,
        spec: &ScenarioSpec,
        envelope: Option<BackendId>,
    ) -> Result<ScenarioSpec, ApiError> {
        let id = match (spec.backend, envelope) {
            (Some(a), Some(b)) if a != b => {
                return Err(ApiError::bad_request(format!(
                    "backend requested twice and disagreeing: the spec \
                     says {:?}, the envelope says {:?}",
                    a.as_str(),
                    b.as_str()
                )))
            }
            (a, b) => a.or(b).unwrap_or(self.core.default_backend),
        };
        let caps = backend::get(id).capabilities();
        if !caps.supports(spec.ask, spec.shape) {
            return Err(ApiError::new(
                ErrorCode::UnsupportedByBackend,
                format!(
                    "backend {:?} does not support ask {:?} with shape \
                     {:?} (ask \"backends\" for the capability table)",
                    id.as_str(),
                    spec.ask.as_str(),
                    spec.shape.as_str()
                ),
            ));
        }
        let mut resolved = spec.clone();
        resolved.backend = Some(id);
        Ok(resolved)
    }

    /// Whether `req` is memoized at request level: only `repro` of
    /// registry entries flagged deterministic. The simulator trio and
    /// `scenario` memoize per sweep point inside the scenario path
    /// instead; `run` (real PJRT execution), introspection, jobs, and
    /// `stats` never cache.
    fn cacheable(&self, req: &Request) -> bool {
        match req {
            Request::Repro { experiment } => experiments::spec(experiment)
                .map_or(false, |s| s.deterministic),
            _ => false,
        }
    }

    /// Validate + enqueue a scenario as an async job. The spec's
    /// backend is resolved and capability-gated here too, so the direct
    /// API path is as strict as the wire. `watch: true` registers a
    /// progress receiver atomically with the enqueue (the serve
    /// transport's push source); `use_cache: false` makes the workers
    /// run every point cold.
    pub fn submit_job(
        &self,
        spec: &ScenarioSpec,
        watch: bool,
        use_cache: bool,
    ) -> Result<(JobView, Option<mpsc::Receiver<JobView>>), ApiError> {
        let spec = self.resolved_spec(spec, None)?;
        self.submit_resolved(spec, watch, use_cache)
    }

    /// Enqueue an already-resolved spec (the transport paths resolve
    /// with the envelope's selector first, so the gate runs exactly
    /// once per submit).
    fn submit_resolved(
        &self,
        spec: ScenarioSpec,
        watch: bool,
        use_cache: bool,
    ) -> Result<(JobView, Option<mpsc::Receiver<JobView>>), ApiError> {
        let points = spec.validated_points()?;
        self.jobs.submit(spec, points.len() as u64, watch, use_cache)
    }

    /// [`Service::submit_job`] as a transport-ready pair honoring the
    /// request envelope (cache flag + backend selector): the response
    /// line to write, plus the progress receiver when the submit was
    /// accepted.
    pub fn submit_watched(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
    ) -> (Response, Option<mpsc::Receiver<JobView>>) {
        let resolved = match self.resolved_spec(spec, env.backend) {
            Ok(s) => s,
            Err(e) => return (Response::from(e), None),
        };
        match self.submit_resolved(resolved, true, env.cache) {
            Ok((view, rx)) => (Response::Job(view), rx),
            Err(e) => (Response::from(e), None),
        }
    }

    /// [`Service::submit_watched`] with a callback frame sink instead
    /// of a channel: the epoll reactor registers its queue-and-wake
    /// forwarder here, so a watched submit costs no pusher thread. The
    /// callback runs under the job-table lock (it must be cheap and
    /// non-blocking) and receives the queued snapshot at registration,
    /// then the same frame sequence the channel path delivers.
    pub fn submit_watched_with(
        &self,
        spec: &ScenarioSpec,
        env: &RequestEnvelope,
        on_frame: Box<dyn Fn(JobView) + Send>,
    ) -> Response {
        let resolved = match self.resolved_spec(spec, env.backend) {
            Ok(s) => s,
            Err(e) => return Response::from(e),
        };
        let points = match resolved.validated_points() {
            Ok(p) => p,
            Err(e) => return Response::from(e),
        };
        match self.jobs.submit_with(
            resolved,
            points.len() as u64,
            Some(super::job::Watcher::Callback(on_frame)),
            env.cache,
        ) {
            Ok(view) => Response::Job(view),
            Err(e) => Response::from(e),
        }
    }

    /// Run the whole experiment registry with up to `workers` driver
    /// threads (the CLI's `repro all`; reports come back in registry
    /// order, byte-identical to a serial run).
    pub fn repro_all(
        &self,
        workers: usize,
    ) -> Vec<experiments::ExperimentReport> {
        // Every driver is a cold engine execution; `stats` must stay
        // truthful for this route too (regression:
        // tests/api_protocol.rs).
        self.core
            .engine_runs
            .fetch_add(experiments::REGISTRY.len() as u64, Ordering::Relaxed);
        experiments::run_all(&self.core.cfg, workers)
    }

    fn try_handle(&self, req: &Request) -> Result<Response, ApiError> {
        match req {
            // Dispatched by handle_one (which carries the envelope's
            // cache flag) before the cold path; handle_one is this
            // method's only caller, so there is deliberately no second
            // execution route here.
            Request::Sim { .. }
            | Request::Plan { .. }
            | Request::Sparsity { .. }
            | Request::Scenario { .. }
            | Request::Submit { .. } => Err(ApiError::bad_request(
                "internal: request routed past its dispatcher",
            )),
            Request::JobStatus { job } => {
                self.jobs.status(*job).map(Response::Job)
            }
            Request::JobResult { job } => self.jobs.result(*job),
            Request::JobCancel { job } => {
                self.jobs.cancel(*job).map(Response::Job)
            }
            Request::Run { entry } => {
                let (reply_tx, reply_rx) = mpsc::channel();
                let sender = self
                    .core
                    .exec_tx
                    .lock()
                    .map_err(|_| {
                        ApiError::new(
                            ErrorCode::Runtime,
                            "executor worker lock poisoned",
                        )
                    })?
                    .clone();
                sender
                    .send(ExecJob { entry: entry.clone(), reply: reply_tx })
                    .map_err(|_| {
                        ApiError::new(
                            ErrorCode::Runtime,
                            "executor worker unavailable",
                        )
                    })?;
                let outcome = reply_rx.recv().map_err(|_| {
                    ApiError::new(
                        ErrorCode::Runtime,
                        "executor worker dropped",
                    )
                })??;
                Ok(Response::Run {
                    entry: outcome.entry,
                    outputs: outcome.outputs,
                    checksum: outcome.checksum,
                    exec_ms: outcome.exec_ms,
                })
            }
            Request::Repro { experiment } => {
                let spec =
                    experiments::spec(experiment).ok_or_else(|| {
                        ApiError::new(
                            ErrorCode::UnknownExperiment,
                            format!(
                                "unknown experiment {experiment:?} (ask \
                                 list_experiments for the registry)"
                            ),
                        )
                    })?;
                self.core.engine_runs.fetch_add(1, Ordering::Relaxed);
                let report = (spec.runner)(&self.core.cfg);
                Ok(Response::Repro {
                    experiment: spec.id.to_string(),
                    title: report.title.clone(),
                    report: report.json.clone(),
                    rendered: report.render(),
                })
            }
            Request::ListExperiments => Ok(Response::Experiments {
                experiments: experiments::REGISTRY
                    .iter()
                    .map(|s| ExperimentInfo {
                        id: s.id.to_string(),
                        title: s.title.to_string(),
                        section: s.section.to_string(),
                        deterministic: s.deterministic,
                    })
                    .collect(),
            }),
            Request::Backends => Ok(Response::Backends {
                backends: backend::REGISTRY
                    .iter()
                    .map(|b| {
                        let c = b.capabilities();
                        BackendInfo {
                            id: c.id.as_str().to_string(),
                            description: c.description.to_string(),
                            asks: c
                                .asks
                                .iter()
                                .map(|a| a.as_str().to_string())
                                .collect(),
                            sim_shapes: c
                                .sim_shapes
                                .iter()
                                .map(|s| s.as_str().to_string())
                                .collect(),
                            deterministic: c.deterministic,
                            default: c.id == self.core.default_backend,
                        }
                    })
                    .collect(),
            }),
            Request::Config => {
                Ok(Response::Config { config: self.core.cfg.to_json() })
            }
            Request::Stats => Ok(Response::Stats {
                cache: self.core.cache.stats(),
                engine_runs: self.engine_runs(),
                backend_runs: self.backend_runs(),
                // Standalone servers never carry the cluster block;
                // only `cluster::Coordinator` fills it.
                cluster: None,
            }),
            // Top-level batches are fanned out by `handle_opts`; a
            // batch reaching this point was nested inside another (the
            // wire decoder rejects that too).
            Request::Batch { .. } => {
                Err(ApiError::bad_request("batches do not nest"))
            }
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // Stop handing out jobs; running jobs cancel between points.
        self.jobs.shutdown();
        for h in self.job_workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// The scenario-backed request kinds and their single-point unwrap
/// flag: v1 requests answer in their v1 shape, `scenario` answers all
/// points.
fn desugar(req: &Request) -> Option<(ScenarioSpec, bool)> {
    match req {
        Request::Sim { n, precision, streams } => {
            Some((ScenarioSpec::sim(*n, *precision, *streams), true))
        }
        Request::Plan { objective, streams, n, precision } => Some((
            ScenarioSpec::plan(*objective, *streams, *n, *precision),
            true,
        )),
        Request::Sparsity { n, streams } => {
            Some((ScenarioSpec::sparsity_question(*n, *streams), true))
        }
        Request::Scenario { spec } => Some((spec.clone(), false)),
        _ => None,
    }
}

/// Unwrap a single-point scenario response back into its v1 shape.
fn unwrap_single(resp: Response) -> Response {
    match resp {
        Response::Scenario { mut points } if points.len() == 1 => {
            *points.remove(0).result
        }
        other => other,
    }
}

impl Core {
    /// Validate, expand, and run a scenario. Points fan out across the
    /// scoped pool in expansion order (results merge back in order, so
    /// the response is byte-identical to a serial run) with per-point
    /// cache consultation.
    fn run_scenario(
        &self,
        spec: &ScenarioSpec,
        use_cache: bool,
    ) -> Result<Response, ApiError> {
        // All-or-nothing: every point must be in range before any runs,
        // so a swept request never half-answers (the same gate `submit`
        // runs).
        let points = spec.validated_points()?;
        let results = pool::scoped_map(
            &points,
            pool::default_workers(),
            |_, p| PointResult {
                point: *p,
                result: Box::new(self.run_point(spec, p, use_cache)),
            },
        );
        Ok(Response::Scenario { points: results })
    }

    /// One validated point through the per-point cache.
    fn run_point(
        &self,
        spec: &ScenarioSpec,
        p: &Point,
        use_cache: bool,
    ) -> Response {
        let mut single = spec.at(p);
        // The auto router resolves to its concrete engine *before*
        // cache-keying and cold-run accounting (routing reads the
        // budgets off `spec`, which `at` strips from the cache form),
        // so routed points share cache entries — and counters — with
        // explicit des/analytic requests; `engine_runs_auto` stays 0
        // by design (DESIGN.md §6.10).
        if single.backend == Some(BackendId::Auto) {
            single.backend = Some(TrustTable::route(spec, p));
        }
        let key =
            Request::Scenario { spec: single.clone() }.cache_key();
        if use_cache {
            if let Some(resp) = self.cache.get(&key) {
                return resp;
            }
        }
        let resp = self.run_point_cold(&single, p);
        if use_cache && !matches!(resp, Response::Error { .. }) {
            self.cache.insert(key, &resp);
        }
        resp
    }

    /// Cold execution of one point — dispatch to the resolved
    /// [`crate::backend::Backend`] (the `des` replay engine or the
    /// `analytic` closed-form fast path) and map its typed result onto
    /// the wire response. Infallible by construction: ranges and
    /// backend capabilities were checked up front. Counts both the
    /// total and the per-backend cold-execution counters.
    fn run_point_cold(&self, spec: &ScenarioSpec, p: &Point) -> Response {
        let id = spec.backend.unwrap_or(self.default_backend);
        let b = backend::get(id);
        self.engine_runs.fetch_add(1, Ordering::Relaxed);
        self.backend_runs[id.index()].fetch_add(1, Ordering::Relaxed);
        match spec.ask {
            Ask::Sim => {
                let r = b.simulate(&self.cfg, spec, p);
                Response::Sim {
                    makespan_ms: r.makespan_ms,
                    speedup_vs_serial: r.speedup_vs_serial,
                    overlap_efficiency: r.overlap_efficiency,
                    fairness: r.fairness,
                    l2_miss: r.l2_miss,
                    lds_util: r.lds_util,
                    transfer_ms: r.transfer_ms,
                    spans: r.spans,
                }
            }
            Ask::Plan => {
                let r = b.plan(&self.cfg, spec, p);
                Response::Plan {
                    objective: objective_name(r.objective).to_string(),
                    sparse: r.sparse,
                    groups: r
                        .groups
                        .into_iter()
                        .map(|g| PlanGroup {
                            kernels: g.kernels,
                            streams: g.streams,
                            expected_fairness: g.expected_fairness,
                            process_isolation: g.process_isolation,
                        })
                        .collect(),
                }
            }
            Ask::Sparsity => {
                let r = b.sparsity(&self.cfg, spec, p);
                Response::Sparsity {
                    enable: r.enable,
                    reason: r.reason,
                    isolated_speedup: r.isolated_speedup,
                    concurrent_speedup: r.concurrent_speedup,
                }
            }
        }
    }
}

/// A job worker: pull queued jobs, run their points sequentially (the
/// progress/cancel granularity), frame watchers via the table. Exits on
/// table shutdown.
fn job_worker(core: &Core, jobs: &JobTable) {
    while let Some((id, spec, use_cache)) = jobs.next_job() {
        let points = spec.expand();
        let mut results = Vec::with_capacity(points.len());
        for p in &points {
            if !jobs.should_continue(id) {
                break;
            }
            let resp = core.run_point(&spec, p, use_cache);
            results.push(PointResult { point: *p, result: Box::new(resp) });
            if !jobs.point_done(id) {
                break;
            }
        }
        if results.len() == points.len() {
            refine_job(core, jobs, id, &spec, &mut results, use_cache);
            jobs.finish(id, Ok(Response::Scenario { points: results }));
        } else {
            // A cancel (or shutdown) was honored mid-sweep.
            jobs.mark_cancelled(id);
        }
    }
}

/// The refinement pass of a **budgeted `auto` job** (DESIGN.md §6.10):
/// phase one answered every point through the trust-table route (the
/// normal `job_worker` loop above — analytic inside the envelope, DES
/// outside), and here the analytic-answered `sim` points are re-run on
/// the DES ascending by [`TrustTable::confidence`] — least trusted
/// first — replacing their results in place and framing watchers via
/// [`JobTable::point_refined`]. A `max_time_ms` budget soft-bounds the
/// pass: no refinement starts past the deadline (the one in flight
/// finishes — points are never half-answered). Unbudgeted or
/// non-`auto` jobs skip the pass entirely, keeping their frame counts
/// untouched.
fn refine_job(
    core: &Core,
    jobs: &JobTable,
    id: u64,
    spec: &ScenarioSpec,
    results: &mut [PointResult],
    use_cache: bool,
) {
    if spec.backend != Some(BackendId::Auto)
        || (spec.max_error.is_none() && spec.max_time_ms.is_none())
    {
        return;
    }
    let mut todo: Vec<usize> = (0..results.len())
        .filter(|&i| {
            TrustTable::wants_refinement(spec, &results[i].point)
        })
        .collect();
    // Stable sort: equal confidences keep expansion order, so the
    // refinement sequence is deterministic.
    todo.sort_by(|&a, &b| {
        TrustTable::confidence(spec, &results[a].point)
            .partial_cmp(&TrustTable::confidence(spec, &results[b].point))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let started = std::time::Instant::now();
    let mut des = spec.clone();
    des.backend = Some(BackendId::Des);
    for i in todo {
        if !jobs.should_continue(id) {
            return;
        }
        if let Some(budget) = spec.max_time_ms {
            if started.elapsed().as_secs_f64() * 1000.0 >= budget {
                return;
            }
        }
        let p = results[i].point;
        results[i].result =
            Box::new(core.run_point(&des, &p, use_cache));
        if !jobs.point_refined(id) {
            return;
        }
    }
}

/// The executor worker: owns the (lazily created) PJRT executor for the
/// service lifetime and services `run` requests one at a time. Exits
/// when the service (the last sender) is dropped.
fn exec_worker(dir: &Path, rx: mpsc::Receiver<ExecJob>) {
    let mut exec: Option<Executor> = None;
    while let Ok(job) = rx.recv() {
        let result = run_artifact(dir, &mut exec, &job.entry);
        // A dropped reply sender just means the requester went away.
        let _ = job.reply.send(result);
    }
}

/// Execute one artifact with the deterministic input pattern. This is
/// the one place artifact-run logic lives; the CLI `run` subcommand and
/// the socket `run` request both land here.
fn run_artifact(
    dir: &Path,
    exec: &mut Option<Executor>,
    entry: &str,
) -> Result<RunOutcome, ApiError> {
    if exec.is_none() {
        *exec = Some(Executor::new(dir).map_err(|e| {
            ApiError::new(
                ErrorCode::Runtime,
                format!("{e} (run `make artifacts` first)"),
            )
        })?);
    }
    let exec = exec.as_mut().unwrap();
    let spec = exec
        .manifest
        .get(entry)
        .ok_or_else(|| {
            ApiError::new(
                ErrorCode::UnknownEntry,
                format!("unknown entry {entry:?} (see `mi300a-char list`)"),
            )
        })?
        .clone();
    let inputs = deterministic_inputs(&spec);
    let t0 = std::time::Instant::now();
    let out = exec
        .run_f32(entry, &inputs)
        .map_err(|e| ApiError::new(ErrorCode::Runtime, e.to_string()))?;
    Ok(RunOutcome {
        entry: entry.to_string(),
        outputs: out.len(),
        checksum: out.iter().map(|&v| v as f64).sum(),
        exec_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Deterministic inputs for an artifact entry — the same pattern the
/// golden tests use: input `i`, element `j` = `((j mod (13+i)) - 6) / 3`.
pub fn deterministic_inputs(spec: &EntrySpec) -> Vec<Vec<f32>> {
    spec.inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            (0..t.elements())
                .map(|j| ((j % (13 + i)) as f32 - 6.0) / 3.0)
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::job::JobState;
    use super::*;
    use crate::isa::Precision;
    use std::time::{Duration, Instant};

    fn svc() -> Service {
        Service::new(Config::mi300a())
    }

    #[test]
    fn sim_answers_with_physical_invariants() {
        let s = svc();
        match s.handle(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        }) {
            Response::Sim { speedup_vs_serial, fairness, .. } => {
                assert!(
                    speedup_vs_serial > 1.0 && speedup_vs_serial < 4.0,
                    "speedup {speedup_vs_serial}"
                );
                assert!((0.0..=1.0).contains(&fairness));
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn trace_scenarios_replay_share_cache_and_refuse_analytic() {
        use crate::replay::Transform;
        use crate::util::json::Json;
        let s = svc();
        let spec = ScenarioSpec::from_json(
            &Json::parse(
                r#"{"shape":"trace","trace":[
                    {"n":512,"stream":0,"issue_ns":0},
                    {"n":256,"stream":1,"issue_ns":1000},
                    {"n":512,"stream":0,"issue_ns":400000}
                ],"sweep":{"transform":["identity","precision_rewrite:fp16"]}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let points = match s.handle(&Request::Scenario { spec: spec.clone() })
        {
            Response::Scenario { points } => points,
            other => panic!("unexpected response: {other:?}"),
        };
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].point.transform, Transform::Identity);
        let sim = |i: usize| match points[i].result.as_ref() {
            Response::Sim { makespan_ms, spans, .. } => {
                (*makespan_ms, *spans)
            }
            other => panic!("unexpected point result: {other:?}"),
        };
        let (id_ms, id_spans) = sim(0);
        let (f16_ms, f16_spans) = sim(1);
        assert_eq!(id_spans, 3, "one span per launch");
        assert_eq!(f16_spans, 3);
        assert!(
            f16_ms > id_ms,
            "rewriting an fp8 trace to fp16 must cost time \
             ({f16_ms} !> {id_ms})"
        );
        // Both points replayed on the DES, cold.
        assert_eq!(s.backend_runs(), vec![2, 0, 0]);
        // The identity point shares its cache entry with the
        // untransformed spec: re-asking plain costs zero cold runs
        // and answers byte-identically.
        let mut plain = spec.clone();
        plain.sweep = Sweep::default();
        let replays =
            match s.handle(&Request::Scenario { spec: plain }) {
                Response::Scenario { points } => points,
                other => panic!("unexpected response: {other:?}"),
            };
        assert_eq!(replays.len(), 1);
        assert_eq!(replays[0].result, points[0].result);
        assert_eq!(s.backend_runs(), vec![2, 0, 0], "cache shared");
        // The analytic backend refuses issue-time replay, typed,
        // before any point runs.
        let mut refused = spec.clone();
        refused.backend = Some(BackendId::Analytic);
        match s.handle(&Request::Scenario { spec: refused }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::UnsupportedByBackend);
                assert!(message.contains("trace"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn out_of_range_streams_is_a_typed_range_error_not_a_clamp() {
        let s = svc();
        match s.handle(&Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 32,
        }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRange);
                assert!(message.contains("1..=16"), "{message}");
                assert!(message.contains("32"), "{message}");
            }
            other => panic!("expected a range error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_experiment_is_typed() {
        match svc().handle(&Request::Repro { experiment: "fig99".into() }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnknownExperiment)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn list_experiments_mirrors_the_registry() {
        match svc().handle(&Request::ListExperiments) {
            Response::Experiments { experiments } => {
                assert_eq!(experiments.len(), experiments::REGISTRY.len());
                assert_eq!(experiments[0].id, "table1");
                assert!(!experiments[0].title.is_empty());
                // The PR-3 purity flag is surfaced on the wire now.
                for (info, spec) in
                    experiments.iter().zip(experiments::REGISTRY)
                {
                    assert_eq!(
                        info.deterministic, spec.deterministic,
                        "{}",
                        spec.id
                    );
                }
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn backends_request_mirrors_the_backend_registry() {
        match svc().handle(&Request::Backends) {
            Response::Backends { backends } => {
                assert_eq!(backends.len(), backend::REGISTRY.len());
                assert_eq!(backends[0].id, "des");
                assert!(backends[0].default, "des is the default");
                assert_eq!(backends[1].id, "analytic");
                assert!(!backends[1].default);
                assert_eq!(backends[2].id, "auto");
                assert!(!backends[2].default);
                assert!(backends
                    .iter()
                    .all(|b| b.deterministic && !b.asks.is_empty()));
            }
            other => panic!("unexpected response: {other:?}"),
        }
        // A service built with another default reports it.
        let s = Service::with_default_backend(
            Config::mi300a(),
            super::CachePolicy::default(),
            BackendId::Analytic,
        );
        match s.handle(&Request::Backends) {
            Response::Backends { backends } => {
                assert!(!backends[0].default && backends[1].default);
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    /// The analytic backend answers the same points with zero DES
    /// executions, counted truthfully per backend — and the two
    /// backends never share cache entries.
    #[test]
    fn analytic_backend_runs_cold_points_without_the_des() {
        let s = svc();
        let mut spec = ScenarioSpec::sparsity_question(256, 2);
        spec.sweep.streams = vec![1, 2, 4];
        let mut analytic = spec.clone();
        analytic.backend = Some(BackendId::Analytic);
        let a = s.handle(&Request::Scenario { spec: analytic });
        assert!(!matches!(a, Response::Error { .. }), "{a:?}");
        assert_eq!(s.engine_runs(), 3);
        assert_eq!(s.backend_runs(), vec![0, 3, 0], "no DES execution");
        // The same sweep on the default backend runs cold again —
        // backends never share entries — and answers identically for
        // the closed-form sparsity ask.
        let d = s.handle(&Request::Scenario { spec });
        assert_eq!(s.backend_runs(), vec![3, 3, 0]);
        assert_eq!(
            a.to_json(None).to_string(),
            d.to_json(None).to_string(),
            "plan/sparsity asks are backend-invariant"
        );
        // Stats surfaces the split.
        match s.handle(&Request::Stats) {
            Response::Stats { engine_runs, backend_runs, .. } => {
                assert_eq!(engine_runs, 6);
                assert_eq!(backend_runs, vec![3, 3, 0]);
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    /// The auto router resolves each point to its concrete engine
    /// before cache-keying and accounting: in-region points run
    /// analytic, out-of-region points run the DES, `engine_runs_auto`
    /// never moves, and routed points share cache entries with
    /// explicit des/analytic requests.
    #[test]
    fn auto_backend_routes_per_point_and_shares_concrete_cache_entries() {
        let s = svc();
        let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        spec.backend = Some(BackendId::Auto);
        spec.sweep.streams = vec![1, 2, 4, 12];
        let a = s.handle(&Request::Scenario { spec });
        assert!(!matches!(a, Response::Error { .. }), "{a:?}");
        assert_eq!(
            s.backend_runs(),
            vec![1, 3, 0],
            "streams 12 is outside the trust region (DES); 1/2/4 are \
             inside (analytic); the router itself never executes"
        );
        // An explicit analytic request for an in-region point hits the
        // routed point's cache entry — zero new cold runs.
        let mut warm = ScenarioSpec::sim(256, Precision::Fp8, 4);
        warm.backend = Some(BackendId::Analytic);
        let w = s.handle(&Request::Scenario { spec: warm });
        assert!(matches!(w, Response::Scenario { .. }), "{w:?}");
        assert_eq!(s.backend_runs(), vec![1, 3, 0], "cache entry shared");
        // Same for an explicit des request at the out-of-region point.
        let mut hot = ScenarioSpec::sim(256, Precision::Fp8, 12);
        hot.backend = Some(BackendId::Des);
        s.handle(&Request::Scenario { spec: hot });
        assert_eq!(s.backend_runs(), vec![1, 3, 0], "cache entry shared");
    }

    /// A budgeted auto job answers every point first (trust-table
    /// routed), then re-runs its low-confidence analytic answers on
    /// the DES, streaming `refined` frames and replacing the stored
    /// results.
    #[test]
    fn budgeted_auto_jobs_refine_low_confidence_points_on_the_des() {
        let s = svc();
        let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        spec.backend = Some(BackendId::Auto);
        spec.max_error = Some(0.45);
        spec.sweep.streams = vec![1, 2, 12];
        let (view, rx) = s.submit_job(&spec, true, true).unwrap();
        let frames: Vec<JobView> = rx.unwrap().iter().collect();
        let last = frames.last().unwrap();
        assert_eq!(last.state, JobState::Done);
        assert_eq!((last.completed, last.total), (3, 3));
        // streams 1 is fully trusted, streams 12 already ran on the
        // DES; only streams 2 wants refinement.
        assert_eq!(last.refined, 1, "{frames:?}");
        assert!(
            frames.iter().any(|f| f.refined == 1
                && f.completed == f.total
                && !f.state.terminal()),
            "the refinement frame streams before the terminal one: \
             {frames:?}"
        );
        match s.handle(&Request::JobStatus { job: view.job }) {
            Response::Job(v) => assert_eq!(v.refined, 1),
            other => panic!("unexpected status: {other:?}"),
        }
        // Phase one: des 1 (streams 12) + analytic 2; refinement adds
        // one DES re-run of the streams-2 point.
        assert_eq!(s.backend_runs(), vec![2, 2, 0]);
        // The refined point landed in the cache under its des key: an
        // explicit des request for it is a pure cache hit.
        let mut des = ScenarioSpec::sim(256, Precision::Fp8, 2);
        des.backend = Some(BackendId::Des);
        s.handle(&Request::Scenario { spec: des });
        assert_eq!(s.backend_runs(), vec![2, 2, 0], "cache entry shared");
        // An unbudgeted auto job never refines (frame counts are the
        // plain N+3).
        let mut plain = ScenarioSpec::sim(256, Precision::Fp8, 2);
        plain.backend = Some(BackendId::Auto);
        plain.sweep.streams = vec![1, 2, 12];
        let (_, rx) = s.submit_job(&plain, true, true).unwrap();
        let frames: Vec<JobView> = rx.unwrap().iter().collect();
        assert_eq!(frames.len(), 3 + 3);
        assert!(frames.iter().all(|f| f.refined == 0), "{frames:?}");
    }

    /// The envelope `"backend"` key reaches desugared v1 requests, and
    /// repeats hit the backend-specific cache entry.
    #[test]
    fn envelope_backend_selects_the_engine_for_v1_requests() {
        let s = svc();
        let req = Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        };
        let env = super::RequestEnvelope {
            backend: Some(BackendId::Analytic),
            ..super::RequestEnvelope::default()
        };
        let cold = s.handle_env(&req, &env);
        assert!(matches!(cold, Response::Sim { .. }), "{cold:?}");
        assert_eq!(s.backend_runs(), vec![0, 1, 0]);
        let warm = s.handle_env(&req, &env);
        assert_eq!(cold, warm);
        assert_eq!(
            s.backend_runs(),
            vec![0, 1, 0],
            "repeat must hit cache"
        );
        // The same request without the selector runs the DES — a
        // different cache entry, a different engine.
        let des = s.handle(&req);
        assert_eq!(s.backend_runs(), vec![1, 1, 0]);
        assert!(matches!(des, Response::Sim { .. }));
    }

    #[test]
    fn unsupported_and_misplaced_backend_selectors_are_typed() {
        let s = svc();
        // The analytic sim refuses the imbalanced pair, before any
        // point runs.
        let mut spec = ScenarioSpec::new(Ask::Sim);
        spec.shape = super::super::scenario::Shape::ImbalancedPair;
        spec.streams = 2;
        spec.backend = Some(BackendId::Analytic);
        match s.handle(&Request::Scenario { spec: spec.clone() }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::UnsupportedByBackend);
                assert!(message.contains("imbalanced_pair"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(s.engine_runs(), 0);
        // Same gate on the job path.
        match s.handle(&Request::Submit { spec, progress: false }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnsupportedByBackend)
            }
            other => panic!("unexpected response: {other:?}"),
        }
        // A backend selector on a non-scenario request is refused.
        let env = super::RequestEnvelope {
            backend: Some(BackendId::Analytic),
            ..super::RequestEnvelope::default()
        };
        match s.handle_env(&Request::Config, &env) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("only applies"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
        // Spec and envelope disagreeing is refused.
        let mut spec = ScenarioSpec::sparsity_question(256, 2);
        spec.backend = Some(BackendId::Des);
        match s.handle_env(&Request::Scenario { spec }, &env) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("twice"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    /// A batch-envelope backend selector routes the scenario-backed
    /// items and is ignored by the rest, so measure-then-read-stats
    /// batches work under any selector.
    #[test]
    fn batch_envelope_backend_applies_to_scenario_items_only() {
        let s = svc();
        let env = super::RequestEnvelope {
            backend: Some(BackendId::Analytic),
            ..super::RequestEnvelope::default()
        };
        let batch = Request::Batch {
            items: vec![
                Request::Sparsity { n: 512, streams: 4 },
                Request::Stats,
            ],
        };
        match s.handle_env(&batch, &env) {
            Response::Batch { items } => {
                assert!(
                    matches!(items[0], Response::Sparsity { .. }),
                    "{:?}",
                    items[0]
                );
                match &items[1] {
                    Response::Stats { backend_runs, .. } => {
                        assert_eq!(
                            backend_runs,
                            &vec![0, 1, 0],
                            "the sparsity item must have run analytic"
                        );
                    }
                    other => panic!("unexpected stats item: {other:?}"),
                }
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn config_response_matches_the_active_config() {
        let s = svc();
        match s.handle(&Request::Config) {
            Response::Config { config } => {
                assert_eq!(config, s.config().to_json())
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn repeat_requests_hit_the_cache_with_zero_reexecution() {
        let s = svc();
        let req = Request::Sparsity { n: 512, streams: 4 };
        let cold = s.handle(&req);
        assert_eq!(s.engine_runs(), 1);
        let warm = s.handle(&req);
        assert_eq!(s.engine_runs(), 1, "second call must not re-execute");
        assert_eq!(cold, warm);
        assert_eq!(
            cold.to_json(None).to_string(),
            warm.to_json(None).to_string(),
            "cached response must re-serialize byte-identically"
        );
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
    }

    #[test]
    fn disabled_cache_always_runs_cold() {
        let s = Service::with_cache_policy(
            Config::mi300a(),
            super::CachePolicy::disabled(),
        );
        let req = Request::Sparsity { n: 512, streams: 4 };
        let a = s.handle(&req);
        let b = s.handle(&req);
        assert_eq!(a, b, "cold runs are still deterministic");
        assert_eq!(s.engine_runs(), 2);
        let stats = s.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (0, 0, 0));
    }

    #[test]
    fn cache_false_escape_hatch_bypasses_a_warm_cache() {
        let s = svc();
        let req = Request::Sparsity { n: 512, streams: 4 };
        let warm = s.handle(&req);
        assert_eq!(s.engine_runs(), 1);
        let bypass = s.handle_opts(&req, false);
        assert_eq!(s.engine_runs(), 2, "bypass must run cold");
        assert_eq!(warm, bypass);
        let stats = s.cache_stats();
        // The bypass counted neither a hit nor a miss.
        assert_eq!((stats.hits, stats.misses), (0, 1));
    }

    #[test]
    fn error_responses_are_not_cached() {
        let s = svc();
        let req = Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 99,
        };
        for _ in 0..2 {
            match s.handle(&req) {
                Response::Error { code, .. } => {
                    assert_eq!(code, ErrorCode::BadRange)
                }
                other => panic!("unexpected response: {other:?}"),
            }
        }
        let stats = s.cache_stats();
        assert_eq!(stats.entries, 0);
        // Scenario validation rejects out-of-range points before the
        // cache is even consulted, so failed requests count nothing.
        assert_eq!((stats.hits, stats.misses), (0, 0));
        assert_eq!(s.engine_runs(), 0);
    }

    #[test]
    fn oversized_batches_are_a_typed_range_error() {
        let s = svc();
        let items =
            vec![Request::Stats; super::MAX_BATCH_ITEMS + 1];
        match s.handle(&Request::Batch { items }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRange);
                assert!(
                    message.contains(&super::MAX_BATCH_ITEMS.to_string()),
                    "{message}"
                );
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    #[test]
    fn run_without_artifacts_is_a_typed_runtime_error() {
        let dir = std::env::temp_dir().join("mi300a_api_service_no_artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let s = Service::with_artifacts_dir(Config::mi300a(), dir);
        match s.handle(&Request::Run { entry: "gemm_fp8_128".into() }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::Runtime)
            }
            other => panic!("unexpected response: {other:?}"),
        }
    }

    // -----------------------------------------------------------------
    // Scenario + job semantics.
    // -----------------------------------------------------------------

    /// A swept scenario answers each point byte-identically to the
    /// equivalent v1 request, and they share cache entries both ways.
    #[test]
    fn sweep_points_match_v1_requests_and_share_the_cache() {
        let s = svc();
        let v1 = s.handle(&Request::Sim {
            n: 256,
            precision: Precision::Fp8,
            streams: 2,
        });
        assert_eq!(s.engine_runs(), 1);

        let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        spec.sweep.streams = vec![1, 2];
        match s.handle(&Request::Scenario { spec }) {
            Response::Scenario { points } => {
                assert_eq!(points.len(), 2);
                assert_eq!(points[0].point.streams, 1);
                assert_eq!(
                    points[1].result.to_item_json().to_string(),
                    v1.to_item_json().to_string(),
                    "sweep point must answer like its v1 request"
                );
            }
            other => panic!("unexpected response: {other:?}"),
        }
        // Only the streams=1 point was new; streams=2 hit the v1 entry.
        assert_eq!(s.engine_runs(), 2);
        assert_eq!(s.cache_stats().hits, 1);
    }

    #[test]
    fn scenario_sweep_rejects_any_out_of_range_point_upfront() {
        let s = svc();
        let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        spec.sweep.streams = vec![1, 99];
        match s.handle(&Request::Scenario { spec }) {
            Response::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRange);
                assert!(message.contains("99"), "{message}");
            }
            other => panic!("unexpected response: {other:?}"),
        }
        assert_eq!(s.engine_runs(), 0, "no point may run on a bad sweep");
    }

    #[test]
    fn repro_all_counts_engine_runs() {
        let s = svc();
        let reports = s.repro_all(2);
        assert_eq!(reports.len(), experiments::REGISTRY.len());
        assert_eq!(
            s.engine_runs(),
            experiments::REGISTRY.len() as u64,
            "repro_all must count every driver execution"
        );
    }

    fn wait_terminal(s: &Service, job: u64) -> JobView {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match s.handle(&Request::JobStatus { job }) {
                Response::Job(v) if v.state.terminal() => return v,
                Response::Job(_) => {}
                other => panic!("unexpected status: {other:?}"),
            }
            assert!(Instant::now() < deadline, "job {job} never finished");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Submit → run → result equals the synchronous scenario answer.
    #[test]
    fn jobs_run_async_and_results_match_the_sync_scenario() {
        let s = svc();
        let mut spec = ScenarioSpec::sparsity_question(256, 2);
        spec.sweep.streams = vec![1, 2, 4];
        let view = match s.handle(&Request::Submit {
            spec: spec.clone(),
            progress: false,
        }) {
            Response::Job(v) => v,
            other => panic!("unexpected submit response: {other:?}"),
        };
        assert_eq!(view.total, 3);
        let done = wait_terminal(&s, view.job);
        assert_eq!(done.state, JobState::Done);
        assert_eq!((done.completed, done.total), (3, 3));
        let via_job = s.handle(&Request::JobResult { job: view.job });
        let sync = s.handle(&Request::Scenario { spec });
        assert_eq!(
            via_job.to_json(None).to_string(),
            sync.to_json(None).to_string(),
            "job result must equal the synchronous sweep"
        );
    }

    #[test]
    fn job_queue_overload_is_typed_and_cancel_clears_queued_jobs() {
        // max_running 0: nothing ever runs, so the queue fills
        // deterministically.
        let s = Service::with_job_limits(
            Config::mi300a(),
            JobLimits { max_running: 0, max_queued: 2, max_finished: 8 },
        );
        let spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        let submit = |s: &Service| {
            s.handle(&Request::Submit { spec: spec.clone(), progress: false })
        };
        let a = match submit(&s) {
            Response::Job(v) => v,
            other => panic!("unexpected: {other:?}"),
        };
        submit(&s);
        match submit(&s) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::Overloaded)
            }
            other => panic!("expected overloaded, got {other:?}"),
        }
        // job_result before it ran: typed not_ready.
        match s.handle(&Request::JobResult { job: a.job }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::NotReady)
            }
            other => panic!("expected not_ready, got {other:?}"),
        }
        // Cancelling a queued job frees its slot immediately.
        match s.handle(&Request::JobCancel { job: a.job }) {
            Response::Job(v) => assert_eq!(v.state, JobState::Cancelled),
            other => panic!("unexpected: {other:?}"),
        }
        match submit(&s) {
            Response::Job(_) => {}
            other => panic!("queue slot was not freed: {other:?}"),
        }
        match s.handle(&Request::JobStatus { job: 999 }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::UnknownJob)
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn running_jobs_cancel_between_points() {
        let s = svc();
        let mut spec = ScenarioSpec::sim(2048, Precision::Fp8, 8);
        // A long sweep (128 heavy points, distinct so none cache) so
        // the immediate cancel lands while the sweep is running.
        spec.sweep.iters = (1..=128).collect();
        let view = match s.handle(&Request::Submit {
            spec,
            progress: false,
        }) {
            Response::Job(v) => v,
            other => panic!("unexpected: {other:?}"),
        };
        match s.handle(&Request::JobCancel { job: view.job }) {
            Response::Job(_) => {}
            other => panic!("unexpected: {other:?}"),
        }
        let done = wait_terminal(&s, view.job);
        assert_eq!(done.state, JobState::Cancelled);
        assert!(
            done.completed < done.total,
            "cancel must land mid-sweep ({}/{})",
            done.completed,
            done.total
        );
        match s.handle(&Request::JobResult { job: view.job }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::NotReady)
            }
            other => panic!("expected not_ready, got {other:?}"),
        }
    }

    /// The `"cache":false` escape hatch reaches job workers: a warm
    /// sweep submitted with cache bypass still runs every point cold.
    #[test]
    fn submit_honors_the_cache_bypass_flag() {
        let s = svc();
        let mut spec = ScenarioSpec::sparsity_question(256, 2);
        spec.sweep.streams = vec![1, 2];
        // Warm the two points synchronously.
        s.handle(&Request::Scenario { spec: spec.clone() });
        assert_eq!(s.engine_runs(), 2);
        let req = Request::Submit { spec, progress: false };
        let view = match s.handle_opts(&req, false) {
            Response::Job(v) => v,
            other => panic!("unexpected submit response: {other:?}"),
        };
        let done = wait_terminal(&s, view.job);
        assert_eq!(done.state, JobState::Done);
        assert_eq!(
            s.engine_runs(),
            4,
            "a cache-bypassing job must run its points cold"
        );
        // A default submit of the same sweep hits the cache instead.
        let mut spec2 = ScenarioSpec::sparsity_question(256, 2);
        spec2.sweep.streams = vec![1, 2];
        let req = Request::Submit { spec: spec2, progress: false };
        let view = match s.handle(&req) {
            Response::Job(v) => v,
            other => panic!("unexpected submit response: {other:?}"),
        };
        let done = wait_terminal(&s, view.job);
        assert_eq!(done.state, JobState::Done);
        assert_eq!(s.engine_runs(), 4, "warm job points must not re-run");
    }

    #[test]
    fn submit_validates_the_spec_synchronously() {
        let s = svc();
        let mut spec = ScenarioSpec::sim(256, Precision::Fp8, 2);
        spec.sweep.streams = vec![0];
        match s.handle(&Request::Submit { spec, progress: false }) {
            Response::Error { code, .. } => {
                assert_eq!(code, ErrorCode::BadRange)
            }
            other => panic!("expected bad_range, got {other:?}"),
        }
    }
}
