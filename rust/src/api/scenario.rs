//! Declarative scenario specifications — the v2 request surface
//! (DESIGN.md §6.6).
//!
//! A [`ScenarioSpec`] describes a workload *composition* instead of a
//! fixed question: what to ask ([`Ask`]: `sim`/`plan`/`sparsity`), the
//! base kernel (`n`, `precision`, `iters`, base [`SparsityMode`]), the
//! stream-set [`Shape`] (homogeneous / imbalanced_pair / mixed_sparse
//! on one APU, data_parallel / pipeline / halo across a multi-APU
//! [`DeviceSet`] — built via [`crate::workload::generator`]), the
//! device set (`device_set`: 1–4 APUs plus an Infinity Fabric
//! [`Topology`], see [`crate::fabric`] and docs/multi_apu.md), the
//! coordinator objective (for `plan` asks), an optional recorded
//! launch timeline (`trace` + a what-if [`Transform`], shape `trace` —
//! see [`crate::replay`] and docs/replay.md), and optional [`Sweep`]
//! axes whose cross-product — hard-capped at [`MAX_SWEEP_POINTS`] —
//! expands into an ordered list of [`Point`]s. The service compiles
//! every point down to the existing coordinator/sim/sparsity layers,
//! so a single-point scenario answers byte-identically to the v1
//! request it generalizes (v1 `sim`/`plan`/`sparsity` requests desugar
//! into exactly such specs inside `api::Service`).
//!
//! Canonical form: decoding fills every default, and encoding always
//! emits the full field set (conditional fields — `backend`,
//! `device_set`, `max_error`, `max_time_ms`, `objective`, `small_n`,
//! `sweep`, `trace`, `transform` — only when applicable), so
//! decode→encode→decode
//! is a fixpoint and semantically identical specs collide on one cache
//! key no matter how they were spelled (`tests/api_protocol.rs`
//! enforces this). The per-point cache key is the canonical wire form
//! of the single-point spec ([`ScenarioSpec::at`]).

use super::protocol::{check_obj_fields, obj, objective_name,
                      parse_objective, precision_wire_name, str_field,
                      usize_field, ApiError, ErrorCode};
use crate::backend::BackendId;
use crate::coordinator::Objective;
use crate::fabric::{DeviceSet, Topology, DEVICE_RANGE};
use crate::isa::Precision;
use crate::replay::{
    TraceErrorKind, TraceRecord, TraceSpec, Transform,
};
use crate::sim::{KernelDesc, SparsityMode};
use crate::util::json::Json;
use crate::workload::generator::StreamSetSpec;
use std::collections::BTreeMap;

/// Hard cap on the sweep cross-product: a bigger sweep is a
/// `bad_range` error at decode time *and* in the service, never a
/// partially-run one.
pub const MAX_SWEEP_POINTS: usize = 256;

/// Accepted per-kernel iteration range for scenarios (v1 requests pin
/// 50/100, well inside).
pub const ITERS_RANGE: (usize, usize) = (1, 10_000);

/// The payload keys a scenario spec may carry (sorted; shared by the
/// request decoder and [`ScenarioSpec::from_json`]).
pub(crate) const SPEC_FIELDS: &[&str] = &[
    "ask", "backend", "device_set", "iters", "max_error", "max_time_ms",
    "n", "objective", "precision", "shape", "small_n", "sparsity",
    "streams", "sweep", "trace", "transform",
];

/// Range check shared by scenario validation (and, transitively, the
/// desugared v1 requests — the error text is part of the v1 contract).
pub(crate) fn check_range(
    what: &str,
    v: usize,
    (lo, hi): (usize, usize),
) -> Result<usize, ApiError> {
    if v < lo || v > hi {
        return Err(ApiError::new(
            ErrorCode::BadRange,
            format!("{what} must be in {lo}..={hi} (got {v})"),
        ));
    }
    Ok(v)
}

/// What question a scenario point asks of the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ask {
    /// DES simulation of the concurrent stream set (v1 `sim`).
    Sim,
    /// Coordinator execution plan over the kernel pool (v1 `plan`).
    Plan,
    /// Context-dependent 2:4 sparsity decision (v1 `sparsity`).
    Sparsity,
}

impl Ask {
    pub const ALL: [Ask; 3] = [Ask::Sim, Ask::Plan, Ask::Sparsity];

    /// Wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Ask::Sim => "sim",
            Ask::Plan => "plan",
            Ask::Sparsity => "sparsity",
        }
    }

    pub fn parse(s: &str) -> Option<Ask> {
        Ask::ALL.iter().copied().find(|a| a.as_str() == s)
    }

    /// Default per-kernel iterations — exactly what the v1 requests
    /// hard-coded (sim 50, plan 100, sparsity 100 via the
    /// `KernelDesc::gemm` default), so desugared v1 requests stay
    /// byte-identical.
    pub fn default_iters(self) -> usize {
        match self {
            Ask::Sim => 50,
            Ask::Plan | Ask::Sparsity => 100,
        }
    }
}

/// Stream-set composition, built via [`crate::workload::generator`].
/// The first three shapes are single-APU; the last three place work
/// across a multi-APU [`DeviceSet`] with Infinity Fabric exchanges
/// modeled by [`crate::fabric`] (docs/multi_apu.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// `streams` identical kernels (the v1 request shape).
    Homogeneous,
    /// One large + one small kernel on the same ACE (paper §6.3);
    /// `streams` is pinned to 2.
    ImbalancedPair,
    /// Alternating sparse/dense streams (paper §7.2 "mixed").
    MixedSparse,
    /// Replicated kernels on every device + an allreduce-style
    /// gradient exchange each iteration.
    DataParallel,
    /// Depth-split stages across devices with inter-stage activation
    /// relays (classic fill/drain pipelining).
    Pipeline,
    /// Row-sharded kernels with a boundary-tile neighbor exchange each
    /// iteration.
    Halo,
    /// Alternating data-sparse SpMM / dense GEMM streams (AsyncSparse
    /// §5: irregular sparse work time-sharing an ACE with regular
    /// dense work). Single-APU, sim-only.
    SpmmMix,
    /// A recorded kernel-launch timeline replayed with its issue
    /// times honored (the spec's `trace` records, rewritten by its
    /// `transform` — [`crate::replay`], docs/replay.md). Single-APU,
    /// sim-only, DES-only.
    Trace,
}

impl Shape {
    pub const ALL: [Shape; 8] = [
        Shape::Homogeneous,
        Shape::ImbalancedPair,
        Shape::MixedSparse,
        Shape::DataParallel,
        Shape::Pipeline,
        Shape::Halo,
        Shape::SpmmMix,
        Shape::Trace,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Shape::Homogeneous => "homogeneous",
            Shape::ImbalancedPair => "imbalanced_pair",
            Shape::MixedSparse => "mixed_sparse",
            Shape::DataParallel => "data_parallel",
            Shape::Pipeline => "pipeline",
            Shape::Halo => "halo",
            Shape::SpmmMix => "spmm_mix",
            Shape::Trace => "trace",
        }
    }

    pub fn parse(s: &str) -> Option<Shape> {
        Shape::ALL.iter().copied().find(|x| x.as_str() == s)
    }

    /// Default stream count when the spec omits `streams`.
    pub fn default_streams(self) -> usize {
        match self {
            Shape::ImbalancedPair => 2,
            _ => 4,
        }
    }

    /// Whether the shape places work across a device set (and so
    /// accepts `devices > 1`; single-device shapes refuse it). All
    /// multi-device shapes degrade gracefully to `devices == 1` — no
    /// transfers, plain single-APU execution — so scaling sweeps can
    /// anchor at one device.
    pub fn is_multi_device(self) -> bool {
        matches!(
            self,
            Shape::DataParallel | Shape::Pipeline | Shape::Halo
        )
    }
}

/// Optional sweep axes. Empty vectors mean "not swept" (the base value
/// is the single point on that axis); points expand as the
/// cross-product in fixed nesting order `devices` → `n` → `precision`
/// → `streams` → `iters` → `transform` (last axis varies fastest;
/// `devices` varies slowest so scaling curves read off in order). The
/// `transform` axis only applies to shape `trace`.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Sweep {
    pub devices: Vec<usize>,
    pub n: Vec<usize>,
    pub precision: Vec<Precision>,
    pub streams: Vec<usize>,
    pub iters: Vec<usize>,
    pub transform: Vec<Transform>,
}

impl Sweep {
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
            && self.n.is_empty()
            && self.precision.is_empty()
            && self.streams.is_empty()
            && self.iters.is_empty()
            && self.transform.is_empty()
    }

    /// Cross-product size (each absent axis counts 1).
    pub fn points(&self) -> usize {
        [
            self.devices.len(),
            self.n.len(),
            self.precision.len(),
            self.streams.len(),
            self.iters.len(),
            self.transform.len(),
        ]
        .iter()
        .fold(1usize, |acc, &len| acc.saturating_mul(len.max(1)))
    }
}

/// One expanded sweep point: the concrete base values a single
/// execution uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Point {
    pub n: usize,
    pub precision: Precision,
    pub streams: usize,
    pub iters: usize,
    /// Devices running the point (1 unless the spec's `device_set` or
    /// a `devices` sweep axis says otherwise).
    pub devices: usize,
    /// What-if trace rewrite (always [`Transform::Identity`] outside
    /// shape `trace`).
    pub transform: Transform,
}

impl Point {
    /// Wire form (`{"iters":..,"n":..,"precision":..,"streams":..}`,
    /// plus a leading `"devices"` only when above 1 and a trailing
    /// `"transform"` only when not `identity` — points keep their
    /// pre-fabric / pre-replay bytes on the old shapes).
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::with_capacity(6);
        if self.devices > 1 {
            fields.push(("devices", Json::Num(self.devices as f64)));
        }
        fields.push(("iters", Json::Num(self.iters as f64)));
        fields.push(("n", Json::Num(self.n as f64)));
        fields.push((
            "precision",
            Json::Str(precision_wire_name(self.precision).into()),
        ));
        fields.push(("streams", Json::Num(self.streams as f64)));
        if self.transform != Transform::Identity {
            fields.push(("transform", Json::Str(self.transform.name())));
        }
        Json::obj(fields)
    }

    /// Strict decode (client side of `scenario` responses).
    pub(crate) fn from_json(v: &Json, what: &str) -> Result<Point, ApiError> {
        let m = obj(v, what)?;
        check_obj_fields(
            m,
            what,
            &["devices", "iters", "n", "precision", "streams", "transform"],
        )?;
        let p = str_field(m, what, "precision")?;
        let devices = if m.contains_key("devices") {
            usize_field(m, what, "devices")?
        } else {
            1
        };
        let transform = if m.contains_key("transform") {
            let t = str_field(m, what, "transform")?;
            Transform::parse(t).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad transform {t:?}"
                ))
            })?
        } else {
            Transform::Identity
        };
        Ok(Point {
            n: usize_field(m, what, "n")?,
            precision: Precision::parse(p).ok_or_else(|| {
                ApiError::bad_request(format!("{what}: bad precision {p:?}"))
            })?,
            streams: usize_field(m, what, "streams")?,
            iters: usize_field(m, what, "iters")?,
            devices,
            transform,
        })
    }
}

/// One answered sweep point: the point coordinates plus the
/// (envelope-less) response the equivalent v1 request would produce.
#[derive(Debug, Clone, PartialEq)]
pub struct PointResult {
    pub point: Point,
    pub result: Box<super::protocol::Response>,
}

/// A declarative scenario: base kernel, stream-set shape, question, and
/// optional sweep axes. See the module docs for the canonical form.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub ask: Ask,
    /// Execution backend answering the points (DESIGN.md §6.8). `None`
    /// means "the serving instance's default" (`des` unless
    /// `serve --backend` overrides it); the service resolves it to a
    /// concrete id before execution, so the canonical single-point
    /// cache form always names its backend and backends never share
    /// cache entries. Omitted from the wire when `None`, which keeps
    /// every pre-backend fixture byte-identical.
    pub backend: Option<BackendId>,
    pub n: usize,
    pub precision: Precision,
    pub iters: usize,
    /// Accuracy budget (DESIGN.md §6.10): the worst relative error the
    /// caller will accept on time-like answers. Only the `auto` backend
    /// consults it — a budget tighter than the trust table's advertised
    /// envelope routes every sim point to the DES, and its presence on
    /// a job arms the refinement pass. Dropped by [`ScenarioSpec::at`],
    /// so budgeted and unbudgeted sweeps share per-point cache entries.
    pub max_error: Option<f64>,
    /// Latency budget in milliseconds: a soft wall-clock bound on the
    /// background refinement pass of a budgeted `auto` job (phase one
    /// always answers every point). Dropped by [`ScenarioSpec::at`]
    /// like `max_error`.
    pub max_time_ms: Option<f64>,
    pub streams: usize,
    pub shape: Shape,
    /// The APUs answering the point and their Infinity Fabric wiring
    /// (DESIGN.md §6.11, docs/multi_apu.md). The single-device default
    /// is omitted from the wire, keeping pre-fabric fixtures
    /// byte-identical; `devices > 1` requires a multi-device shape.
    pub device_set: DeviceSet,
    /// Small-kernel size for `imbalanced_pair` (default `n/4`, min 64,
    /// computed per point when absent).
    pub small_n: Option<usize>,
    /// Present exactly when `ask` is [`Ask::Plan`].
    pub objective: Option<Objective>,
    /// Base sparsity overlay (for `mixed_sparse`, the mode of the
    /// sparse streams; `dense` there means the generator's default
    /// `lhs`).
    pub sparsity: SparsityMode,
    pub sweep: Sweep,
    /// The recorded launch timeline (shape `trace` only, required
    /// there — see [`crate::replay::format`]). Omitted from the wire
    /// when empty, keeping every pre-replay fixture byte-identical.
    /// On decode, `n` / `precision` / `streams` / `iters` are
    /// *normalized from the trace* (max n, FLOP-dominant precision,
    /// stream count, 1) so spelling variants collide on one cache key.
    pub trace: Vec<TraceRecord>,
    /// What-if rewrite applied to `trace` before replay
    /// (docs/replay.md); `identity` stays off the wire.
    pub transform: Transform,
}

impl ScenarioSpec {
    /// A single-point spec with the ask's defaults (n 512, FP8,
    /// 4 streams, homogeneous, dense, no sweep).
    pub fn new(ask: Ask) -> ScenarioSpec {
        ScenarioSpec {
            ask,
            backend: None,
            n: 512,
            precision: Precision::Fp8,
            iters: ask.default_iters(),
            max_error: None,
            max_time_ms: None,
            streams: 4,
            shape: Shape::Homogeneous,
            device_set: DeviceSet::default(),
            small_n: None,
            objective: if ask == Ask::Plan {
                Some(Objective::LatencySensitive)
            } else {
                None
            },
            sparsity: SparsityMode::Dense,
            sweep: Sweep::default(),
            trace: Vec::new(),
            transform: Transform::Identity,
        }
    }

    /// A trace-replay spec over `records` (shape `trace`, ask `sim`),
    /// with the headline fields normalized from the validated trace —
    /// the programmatic twin of decoding a `{"shape":"trace",...}`
    /// payload. The records are validated up front; defects map to the
    /// same typed errors the wire decoder produces.
    pub fn trace_replay(
        records: Vec<TraceRecord>,
    ) -> Result<ScenarioSpec, ApiError> {
        let mut s = ScenarioSpec::new(Ask::Sim);
        s.shape = Shape::Trace;
        s.trace = records;
        s.normalize_trace_fields("trace spec")?;
        Ok(s)
    }

    /// Re-derive the headline fields from the (validated) trace:
    /// `streams` := stream count, `n` := max n, `precision` :=
    /// FLOP-dominant precision, `iters` := 1. Called on every decode
    /// of a trace-shaped spec, so semantically identical trace specs
    /// collide on one canonical form no matter how the headline
    /// fields were spelled.
    pub(crate) fn normalize_trace_fields(
        &mut self,
        what: &str,
    ) -> Result<(), ApiError> {
        let ts = TraceSpec::from_records(self.trace.clone())
            .map_err(|e| trace_api_error(what, &e))?;
        self.streams = ts.stream_count();
        self.n = ts.max_n();
        self.precision = ts.dominant_precision();
        self.iters = 1;
        Ok(())
    }

    /// The exact desugaring of a v1 `sim` request.
    pub fn sim(n: usize, precision: Precision, streams: usize) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(Ask::Sim);
        s.n = n;
        s.precision = precision;
        s.streams = streams;
        s
    }

    /// The exact desugaring of a v1 `plan` request.
    pub fn plan(
        objective: Objective,
        streams: usize,
        n: usize,
        precision: Precision,
    ) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(Ask::Plan);
        s.objective = Some(objective);
        s.streams = streams;
        s.n = n;
        s.precision = precision;
        s
    }

    /// The exact desugaring of a v1 `sparsity` request (FP8 candidate,
    /// like the v1 handler).
    pub fn sparsity_question(n: usize, streams: usize) -> ScenarioSpec {
        let mut s = ScenarioSpec::new(Ask::Sparsity);
        s.n = n;
        s.streams = streams;
        s
    }

    /// Structural validation (field combinations + the sweep cap).
    /// Numeric ranges are per-point ([`ScenarioSpec::check_point`]).
    pub fn validate(&self) -> Result<(), ApiError> {
        if self.objective.is_some() != (self.ask == Ask::Plan) {
            return Err(ApiError::bad_request(if self.ask == Ask::Plan {
                "\"objective\" is required when ask is \"plan\"".to_string()
            } else {
                format!(
                    "\"objective\" only applies to ask \"plan\" (ask is \
                     {:?})",
                    self.ask.as_str()
                )
            }));
        }
        if self.small_n.is_some() && self.shape != Shape::ImbalancedPair {
            return Err(ApiError::bad_request(
                "\"small_n\" only applies to shape \"imbalanced_pair\"",
            ));
        }
        if self.ask == Ask::Sparsity {
            if self.sparsity != SparsityMode::Dense {
                return Err(ApiError::bad_request(
                    "ask \"sparsity\" evaluates a dense candidate kernel; \
                     \"sparsity\" must be \"dense\"",
                ));
            }
            if self.shape != Shape::Homogeneous {
                return Err(ApiError::bad_request(
                    "ask \"sparsity\" evaluates a homogeneous candidate; \
                     use shape \"homogeneous\"",
                ));
            }
        }
        if matches!(self.shape, Shape::SpmmMix | Shape::Trace)
            && self.ask != Ask::Sim
        {
            return Err(ApiError::bad_request(format!(
                "shape {:?} only applies to ask \"sim\"",
                self.shape.as_str()
            )));
        }
        if (self.shape == Shape::Trace) != !self.trace.is_empty() {
            return Err(ApiError::bad_request(
                if self.shape == Shape::Trace {
                    "shape \"trace\" requires a \"trace\" record array"
                        .to_string()
                } else {
                    format!(
                        "\"trace\" only applies to shape \"trace\" \
                         (shape is {:?})",
                        self.shape.as_str()
                    )
                },
            ));
        }
        if self.shape != Shape::Trace
            && (self.transform != Transform::Identity
                || !self.sweep.transform.is_empty())
        {
            return Err(ApiError::bad_request(format!(
                "\"transform\" only applies to shape \"trace\" (shape \
                 is {:?})",
                self.shape.as_str()
            )));
        }
        if self.shape == Shape::Trace {
            // The timeline pins its own geometry; only the transform
            // axis makes sense to sweep.
            if !(self.sweep.devices.is_empty()
                && self.sweep.n.is_empty()
                && self.sweep.precision.is_empty()
                && self.sweep.streams.is_empty()
                && self.sweep.iters.is_empty())
            {
                return Err(ApiError::bad_request(
                    "shape \"trace\" fixes n/precision/streams/iters/\
                     devices from the trace; only the \"transform\" \
                     sweep axis applies",
                ));
            }
            TraceSpec::from_records(self.trace.clone())
                .map_err(|e| trace_api_error("trace", &e))?;
        }
        check_range(
            "device_set.devices",
            self.device_set.devices,
            DEVICE_RANGE,
        )?;
        let multi_device = self.device_set.devices > 1
            || !self.sweep.devices.is_empty();
        if multi_device && !self.shape.is_multi_device() {
            return Err(ApiError::bad_request(format!(
                "shape {:?} is single-device; devices > 1 (or a devices \
                 sweep axis) wants shape \
                 data_parallel|pipeline|halo",
                self.shape.as_str()
            )));
        }
        if self.shape.is_multi_device() && self.ask != Ask::Sim {
            return Err(ApiError::bad_request(format!(
                "multi-device shape {:?} only applies to ask \"sim\"",
                self.shape.as_str()
            )));
        }
        if self.shape == Shape::ImbalancedPair {
            if !self.sweep.streams.is_empty() {
                return Err(ApiError::bad_request(
                    "shape \"imbalanced_pair\" pins streams to 2; remove \
                     the streams sweep axis",
                ));
            }
            if self.streams != 2 {
                return Err(ApiError::new(
                    ErrorCode::BadRange,
                    format!(
                        "shape \"imbalanced_pair\" pins streams to 2 (got \
                         {})",
                        self.streams
                    ),
                ));
            }
        }
        for (key, v) in [
            ("max_error", self.max_error),
            ("max_time_ms", self.max_time_ms),
        ] {
            if let Some(x) = v {
                if !(x.is_finite() && x > 0.0) {
                    return Err(ApiError::new(
                        ErrorCode::BadRange,
                        format!(
                            "{key:?} must be a positive number (got {x})"
                        ),
                    ));
                }
            }
        }
        let points = self.sweep.points();
        if points > MAX_SWEEP_POINTS {
            return Err(ApiError::new(
                ErrorCode::BadRange,
                format!(
                    "sweep expands to {points} points, cap is \
                     {MAX_SWEEP_POINTS}"
                ),
            ));
        }
        Ok(())
    }

    /// Range-check one point. The check *order* per ask mirrors the v1
    /// handlers exactly, so desugared v1 requests keep their error
    /// bytes (`n` first for sim/sparsity, `streams` first for plan).
    pub fn check_point(&self, p: &Point) -> Result<(), ApiError> {
        use super::service::{POOL_STREAMS, SIM_STREAMS, SIZE_RANGE};
        match self.ask {
            Ask::Sim => {
                check_range("n", p.n, SIZE_RANGE)?;
                check_range("streams", p.streams, SIM_STREAMS)?;
            }
            Ask::Plan => {
                check_range("streams", p.streams, POOL_STREAMS)?;
                check_range("n", p.n, SIZE_RANGE)?;
            }
            Ask::Sparsity => {
                check_range("n", p.n, SIZE_RANGE)?;
                check_range("streams", p.streams, POOL_STREAMS)?;
            }
        }
        check_range("iters", p.iters, ITERS_RANGE)?;
        check_range("devices", p.devices, DEVICE_RANGE)?;
        if let Some(s) = self.small_n {
            check_range("small_n", s, SIZE_RANGE)?;
        }
        Ok(())
    }

    /// The all-or-nothing gate both the synchronous scenario path and
    /// job submission run: validate structurally, expand, and
    /// range-check every point before anything executes.
    pub fn validated_points(&self) -> Result<Vec<Point>, ApiError> {
        self.validate()?;
        let points = self.expand();
        for p in &points {
            self.check_point(p)?;
        }
        Ok(points)
    }

    /// Expand the sweep cross-product into ordered points (axis nesting
    /// `devices` → `n` → `precision` → `streams` → `iters` →
    /// `transform`; absent axes contribute the base value). A
    /// sweep-less spec expands to one point.
    pub fn expand(&self) -> Vec<Point> {
        let ds = if self.sweep.devices.is_empty() {
            vec![self.device_set.devices]
        } else {
            self.sweep.devices.clone()
        };
        let ns = if self.sweep.n.is_empty() {
            vec![self.n]
        } else {
            self.sweep.n.clone()
        };
        let ps = if self.sweep.precision.is_empty() {
            vec![self.precision]
        } else {
            self.sweep.precision.clone()
        };
        let ss = if self.sweep.streams.is_empty() {
            vec![self.streams]
        } else {
            self.sweep.streams.clone()
        };
        let is = if self.sweep.iters.is_empty() {
            vec![self.iters]
        } else {
            self.sweep.iters.clone()
        };
        let ts = if self.sweep.transform.is_empty() {
            vec![self.transform]
        } else {
            self.sweep.transform.clone()
        };
        let mut out = Vec::with_capacity(self.sweep.points());
        for &devices in &ds {
            for &n in &ns {
                for &precision in &ps {
                    for &streams in &ss {
                        for &iters in &is {
                            for &transform in &ts {
                                out.push(Point {
                                    n,
                                    precision,
                                    streams,
                                    iters,
                                    devices,
                                    transform,
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The canonical single-point spec at `p` (sweep cleared, base
    /// fields replaced, budgets dropped) — its wire form is the
    /// per-point cache key. Budgets steer *routing and refinement*,
    /// never a point's answer, so budgeted and unbudgeted sweeps
    /// share cache entries; the service resolves `backend:"auto"` to
    /// its routed concrete id before keying for the same reason.
    pub fn at(&self, p: &Point) -> ScenarioSpec {
        let mut s = self.clone();
        s.n = p.n;
        s.precision = p.precision;
        s.streams = p.streams;
        s.iters = p.iters;
        s.device_set =
            DeviceSet::normalized(p.devices, self.device_set.topology);
        s.max_error = None;
        s.max_time_ms = None;
        s.sweep = Sweep::default();
        s.transform = p.transform;
        s
    }

    /// Build the concrete kernel set for one point via
    /// [`crate::workload::generator`].
    pub fn kernels(&self, p: &Point) -> Vec<KernelDesc> {
        let overlay = |set: StreamSetSpec| {
            if self.sparsity.is_sparse() {
                set.with_sparsity(self.sparsity)
            } else {
                set
            }
        };
        match self.shape {
            Shape::Homogeneous => {
                overlay(StreamSetSpec::homogeneous(
                    KernelDesc::gemm(p.n, p.precision).with_iters(p.iters),
                    p.streams,
                ))
                .kernels
            }
            Shape::ImbalancedPair => {
                let small = self.small_n.unwrap_or((p.n / 4).max(64));
                overlay(StreamSetSpec::imbalanced_pair(
                    p.n,
                    small,
                    p.precision,
                    p.iters,
                ))
                .kernels
            }
            Shape::MixedSparse => {
                let mode = if self.sparsity == SparsityMode::Dense {
                    SparsityMode::SparseLhs
                } else {
                    self.sparsity
                };
                let mut ks = StreamSetSpec::mixed_sparse(
                    p.n,
                    p.precision,
                    p.streams,
                    p.iters,
                )
                .kernels;
                if mode != SparsityMode::SparseLhs {
                    for k in &mut ks {
                        if k.sparsity.is_sparse() {
                            k.sparsity = mode;
                        }
                    }
                }
                ks
            }
            // Multi-device placements are uniform (replica / K-split /
            // M-shard), so one kernel set describes every device and
            // the engine replays it once per point.
            Shape::DataParallel => {
                overlay(StreamSetSpec::data_parallel_replica(
                    p.n,
                    p.precision,
                    p.streams,
                    p.iters,
                ))
                .kernels
            }
            Shape::Pipeline => {
                overlay(StreamSetSpec::pipeline_stage(
                    p.n,
                    p.precision,
                    p.devices,
                    p.streams,
                    p.iters,
                ))
                .kernels
            }
            Shape::Halo => {
                overlay(StreamSetSpec::halo_shard(
                    p.n,
                    p.precision,
                    p.devices,
                    p.streams,
                    p.iters,
                ))
                .kernels
            }
            Shape::SpmmMix => {
                overlay(StreamSetSpec::spmm_mix(
                    p.n,
                    p.precision,
                    p.streams,
                    p.iters,
                ))
                .kernels
            }
            // One descriptor per launch, transform applied — the DES
            // replay path builds its own timeline from the trace, but
            // this keeps `kernels` total for introspection.
            Shape::Trace => p
                .transform
                .apply(&self.trace)
                .iter()
                .map(|r| r.kernel_desc())
                .collect(),
        }
    }

    /// Canonical payload object (no envelope, no `type`) — what spec
    /// files contain and what `"spec"` carries inside `submit`.
    pub fn to_json(&self) -> Json {
        let mut fields = Vec::new();
        self.push_payload(&mut fields);
        Json::obj(fields)
    }

    /// Push the canonical payload fields (shared with the request
    /// encoder in `protocol.rs`).
    pub(crate) fn push_payload(
        &self,
        fields: &mut Vec<(&'static str, Json)>,
    ) {
        fields.push(("ask", Json::Str(self.ask.as_str().into())));
        if let Some(b) = self.backend {
            fields.push(("backend", Json::Str(b.as_str().into())));
        }
        if !self.device_set.is_default() {
            fields.push((
                "device_set",
                Json::obj(vec![
                    (
                        "devices",
                        Json::Num(self.device_set.devices as f64),
                    ),
                    (
                        "topology",
                        Json::Str(
                            self.device_set.topology.as_str().into(),
                        ),
                    ),
                ]),
            ));
        }
        fields.push(("iters", Json::Num(self.iters as f64)));
        if let Some(e) = self.max_error {
            fields.push(("max_error", Json::Num(e)));
        }
        if let Some(t) = self.max_time_ms {
            fields.push(("max_time_ms", Json::Num(t)));
        }
        fields.push(("n", Json::Num(self.n as f64)));
        if let Some(o) = self.objective {
            fields.push(("objective", Json::Str(objective_name(o).into())));
        }
        fields.push((
            "precision",
            Json::Str(precision_wire_name(self.precision).into()),
        ));
        fields.push(("shape", Json::Str(self.shape.as_str().into())));
        if let Some(s) = self.small_n {
            fields.push(("small_n", Json::Num(s as f64)));
        }
        fields.push(("sparsity", Json::Str(self.sparsity.name().into())));
        fields.push(("streams", Json::Num(self.streams as f64)));
        if !self.sweep.is_empty() {
            let mut sw = Vec::new();
            if !self.sweep.devices.is_empty() {
                sw.push(("devices", usize_arr(&self.sweep.devices)));
            }
            if !self.sweep.iters.is_empty() {
                sw.push(("iters", usize_arr(&self.sweep.iters)));
            }
            if !self.sweep.n.is_empty() {
                sw.push(("n", usize_arr(&self.sweep.n)));
            }
            if !self.sweep.precision.is_empty() {
                sw.push((
                    "precision",
                    Json::Arr(
                        self.sweep
                            .precision
                            .iter()
                            .map(|&p| {
                                Json::Str(precision_wire_name(p).into())
                            })
                            .collect(),
                    ),
                ));
            }
            if !self.sweep.streams.is_empty() {
                sw.push(("streams", usize_arr(&self.sweep.streams)));
            }
            if !self.sweep.transform.is_empty() {
                sw.push((
                    "transform",
                    Json::Arr(
                        self.sweep
                            .transform
                            .iter()
                            .map(|t| Json::Str(t.name()))
                            .collect(),
                    ),
                ));
            }
            fields.push(("sweep", Json::obj(sw)));
        }
        if !self.trace.is_empty() {
            fields.push((
                "trace",
                Json::Arr(self.trace.iter().map(|r| r.to_json()).collect()),
            ));
        }
        if self.transform != Transform::Identity {
            fields.push(("transform", Json::Str(self.transform.name())));
        }
    }

    /// Decode a bare spec object (a spec file or the `"spec"` value of
    /// a `submit`). Tolerates an optional `"type":"scenario"` tag so a
    /// captured request payload is a valid spec file; everything else
    /// is strict.
    pub fn from_json(v: &Json) -> Result<ScenarioSpec, ApiError> {
        let what = "scenario spec";
        let m = obj(v, what)?;
        let mut allowed: Vec<&str> = SPEC_FIELDS.to_vec();
        allowed.push("type");
        check_obj_fields(m, what, &allowed)?;
        if let Some(t) = m.get("type") {
            if t.as_str() != Some("scenario") {
                return Err(ApiError::bad_request(format!(
                    "{what}: \"type\" must be \"scenario\" when present"
                )));
            }
        }
        ScenarioSpec::decode_fields(m, what)
    }

    /// Decode the spec fields out of `m` (unknown-field filtering is
    /// the caller's job — the request decoder exempts envelope keys,
    /// [`ScenarioSpec::from_json`] tolerates `type`). Ends with
    /// [`ScenarioSpec::validate`], so a decoded spec is always
    /// structurally sound.
    pub(crate) fn decode_fields(
        m: &BTreeMap<String, Json>,
        what: &str,
    ) -> Result<ScenarioSpec, ApiError> {
        let ask = match opt_str(m, what, "ask")? {
            None => Ask::Sim,
            Some(s) => Ask::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad ask {s:?} (want sim|plan|sparsity)"
                ))
            })?,
        };
        let shape = match opt_str(m, what, "shape")? {
            None => Shape::Homogeneous,
            Some(s) => Shape::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad shape {s:?} (want \
                     homogeneous|imbalanced_pair|mixed_sparse|\
                     data_parallel|pipeline|halo|spmm_mix|trace)"
                ))
            })?,
        };
        let backend = match opt_str(m, what, "backend")? {
            None => None,
            Some(s) => Some(BackendId::parse(s).ok_or_else(|| {
                ApiError::new(
                    ErrorCode::UnknownBackend,
                    format!(
                        "{what}: unknown backend {s:?} (registered: {})",
                        BackendId::names()
                    ),
                )
            })?),
        };
        // `n` is the one required base field — except under shape
        // `trace`, where every headline field is normalized from the
        // trace records below and may simply be omitted.
        let n = if shape == Shape::Trace && !m.contains_key("n") {
            1
        } else {
            usize_field(m, what, "n")?
        };
        let precision = match opt_str(m, what, "precision")? {
            None => Precision::Fp8,
            Some(s) => Precision::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad precision {s:?}"
                ))
            })?,
        };
        let iters = opt_usize(m, what, "iters")?
            .unwrap_or_else(|| ask.default_iters());
        let max_error = opt_f64(m, what, "max_error")?;
        let max_time_ms = opt_f64(m, what, "max_time_ms")?;
        let streams = opt_usize(m, what, "streams")?
            .unwrap_or_else(|| shape.default_streams());
        let small_n = opt_usize(m, what, "small_n")?;
        let objective = match opt_str(m, what, "objective")? {
            None => {
                if ask == Ask::Plan {
                    Some(Objective::LatencySensitive)
                } else {
                    None
                }
            }
            Some(s) => Some(parse_objective(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad objective {s:?} (want \
                     latency|throughput|isolation)"
                ))
            })?),
        };
        let sparsity = match opt_str(m, what, "sparsity")? {
            None => SparsityMode::Dense,
            Some(s) => SparsityMode::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad sparsity {s:?} (want dense|lhs|rhs|both)"
                ))
            })?,
        };
        let sweep = match m.get("sweep") {
            None => Sweep::default(),
            Some(v) => decode_sweep(v, what)?,
        };
        let device_set = match m.get("device_set") {
            None => DeviceSet::default(),
            Some(v) => decode_device_set(v, what)?,
        };
        let trace = match m.get("trace") {
            None => Vec::new(),
            Some(v) => decode_trace(v, what)?,
        };
        let transform = match opt_str(m, what, "transform")? {
            None => Transform::Identity,
            Some(s) => Transform::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: bad transform {s:?} (want identity|\
                     precision_rewrite:<precision>|sparsity_enable|\
                     stream_remap:K|dilate:K|compress:K)"
                ))
            })?,
        };
        let mut spec = ScenarioSpec {
            ask,
            backend,
            n,
            precision,
            iters,
            max_error,
            max_time_ms,
            streams,
            shape,
            device_set,
            small_n,
            objective,
            sparsity,
            sweep,
            trace,
            transform,
        };
        if spec.shape == Shape::Trace && !spec.trace.is_empty() {
            spec.normalize_trace_fields(what)?;
        }
        spec.validate().map_err(|e| {
            ApiError::new(e.code, format!("{what}: {}", e.message))
        })?;
        Ok(spec)
    }
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn decode_sweep(v: &Json, what: &str) -> Result<Sweep, ApiError> {
    let m = obj(v, &format!("{what}: \"sweep\""))?;
    check_obj_fields(
        m,
        &format!("{what}: sweep"),
        &["devices", "iters", "n", "precision", "streams", "transform"],
    )?;
    let axis_usize = |key: &str| -> Result<Vec<usize>, ApiError> {
        match m.get(key) {
            None => Ok(Vec::new()),
            Some(v) => {
                let arr = axis_arr(v, what, key)?;
                arr.iter()
                    .map(|x| match x {
                        Json::Num(f)
                            if f.fract() == 0.0
                                && *f >= 0.0
                                && *f <= 9.0e15 =>
                        {
                            Ok(*f as usize)
                        }
                        _ => Err(ApiError::bad_request(format!(
                            "{what}: sweep axis {key:?} wants \
                             nonnegative integers"
                        ))),
                    })
                    .collect()
            }
        }
    };
    let precision = match m.get("precision") {
        None => Vec::new(),
        Some(v) => {
            let arr = axis_arr(v, what, "precision")?;
            arr.iter()
                .map(|x| {
                    x.as_str().and_then(Precision::parse).ok_or_else(|| {
                        ApiError::bad_request(format!(
                            "{what}: sweep axis \"precision\" wants \
                             precision names"
                        ))
                    })
                })
                .collect::<Result<Vec<_>, _>>()?
        }
    };
    let transform = match m.get("transform") {
        None => Vec::new(),
        Some(v) => {
            let arr = axis_arr(v, what, "transform")?;
            arr.iter()
                .map(|x| {
                    x.as_str().and_then(Transform::parse).ok_or_else(|| {
                        ApiError::bad_request(format!(
                            "{what}: sweep axis \"transform\" wants \
                             transform names (identity|\
                             precision_rewrite:<precision>|\
                             sparsity_enable|stream_remap:K|dilate:K|\
                             compress:K)"
                        ))
                    })
                })
                .collect::<Result<Vec<_>, _>>()?
        }
    };
    Ok(Sweep {
        devices: axis_usize("devices")?,
        n: axis_usize("n")?,
        precision,
        streams: axis_usize("streams")?,
        iters: axis_usize("iters")?,
        transform,
    })
}

/// Decode the `"trace"` record array (strict per-record decode with
/// the record index in every message; the `TraceSpec` bounds and
/// monotonicity run during normalization/validation).
fn decode_trace(
    v: &Json,
    what: &str,
) -> Result<Vec<TraceRecord>, ApiError> {
    let arr = match v {
        Json::Arr(a) => a.as_slice(),
        _ => {
            return Err(ApiError::bad_request(format!(
                "{what}: field \"trace\" must be an array of record \
                 objects"
            )))
        }
    };
    arr.iter()
        .enumerate()
        .map(|(i, r)| {
            TraceRecord::from_json(r).map_err(|e| {
                trace_api_error(&format!("{what}: trace record {i}"), &e)
            })
        })
        .collect()
}

/// Map a replay-layer trace defect onto the wire error classes.
fn trace_api_error(what: &str, e: &crate::replay::TraceError) -> ApiError {
    let code = match e.kind {
        TraceErrorKind::BadRequest => ErrorCode::BadRequest,
        TraceErrorKind::BadRange => ErrorCode::BadRange,
    };
    ApiError::new(code, format!("{what}: {}", e.msg))
}

/// Decode a `"device_set"` object. Both subfields are optional
/// (`devices` defaults to 1, `topology` to `fully_connected`). The
/// decoded set is kept as written — `devices:1` with an explicit
/// topology stays on the wire so a `devices` sweep axis can still
/// reach it; only the per-point cache form ([`ScenarioSpec::at`])
/// normalizes single-device sets down to the omitted default.
fn decode_device_set(v: &Json, what: &str) -> Result<DeviceSet, ApiError> {
    let what_ds = format!("{what}: \"device_set\"");
    let m = obj(v, &what_ds)?;
    check_obj_fields(m, &what_ds, &["devices", "topology"])?;
    let devices = opt_usize(m, &what_ds, "devices")?.unwrap_or(1);
    let topology = match opt_str(m, &what_ds, "topology")? {
        None => Topology::default(),
        Some(s) => Topology::parse(s).ok_or_else(|| {
            ApiError::bad_request(format!(
                "{what_ds}: bad topology {s:?} (want \
                 fully_connected|ring)"
            ))
        })?,
    };
    Ok(DeviceSet { devices, topology })
}

fn axis_arr<'a>(
    v: &'a Json,
    what: &str,
    key: &str,
) -> Result<&'a [Json], ApiError> {
    match v {
        Json::Arr(a) if !a.is_empty() => Ok(a.as_slice()),
        Json::Arr(_) => Err(ApiError::bad_request(format!(
            "{what}: sweep axis {key:?} must not be empty"
        ))),
        _ => Err(ApiError::bad_request(format!(
            "{what}: sweep axis {key:?} must be an array"
        ))),
    }
}

// ---------------------------------------------------------------------
// Optional-field helpers (the strict required-field family lives in
// protocol.rs and is shared).
// ---------------------------------------------------------------------

fn opt_f64(
    m: &BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> Result<Option<f64>, ApiError> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Num(x)) => Ok(Some(*x)),
        Some(_) => Err(ApiError::bad_request(format!(
            "{what}: field {key:?} must be a number"
        ))),
    }
}

fn opt_usize(
    m: &BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> Result<Option<usize>, ApiError> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Num(x))
            if x.fract() == 0.0 && *x >= 0.0 && *x <= 9.0e15 =>
        {
            Ok(Some(*x as usize))
        }
        Some(_) => Err(ApiError::bad_request(format!(
            "{what}: field {key:?} must be a nonnegative integer"
        ))),
    }
}


fn opt_str<'a>(
    m: &'a BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> Result<Option<&'a str>, ApiError> {
    match m.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s.as_str())),
        Some(_) => Err(ApiError::bad_request(format!(
            "{what}: field {key:?} must be a string"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_spec_decodes_with_defaults_and_is_a_fixpoint() {
        let v = Json::parse(r#"{"n":512}"#).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec, ScenarioSpec::sim(512, Precision::Fp8, 4));
        let canonical = spec.to_json().to_string();
        assert_eq!(
            canonical,
            r#"{"ask":"sim","iters":50,"n":512,"precision":"fp8","shape":"homogeneous","sparsity":"dense","streams":4}"#
        );
        let back =
            ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
                .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
    }

    #[test]
    fn backend_field_canonicalizes_and_unknown_ids_are_typed() {
        use crate::backend::BackendId;
        let v = Json::parse(r#"{"n":512,"backend":"analytic"}"#).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.backend, Some(BackendId::Analytic));
        let canonical = spec.to_json().to_string();
        assert!(
            canonical.contains(r#""backend":"analytic""#),
            "{canonical}"
        );
        let back = ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
            .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // An omitted backend stays omitted, keeping every pre-backend
        // wire fixture byte-identical.
        let plain = ScenarioSpec::sim(512, Precision::Fp8, 4);
        assert!(!plain.to_json().to_string().contains("backend"));
        // Unknown ids are the typed unknown_backend error naming the
        // registry.
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"backend":"slide_rule"}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownBackend);
        assert!(err.message.contains("slide_rule"), "{err}");
        assert!(err.message.contains("des"), "{err}");
    }

    #[test]
    fn budget_fields_canonicalize_and_are_dropped_from_cache_points() {
        let v = Json::parse(
            r#"{"n":512,"backend":"auto","max_error":0.25,"max_time_ms":1500}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.max_error, Some(0.25));
        assert_eq!(spec.max_time_ms, Some(1500.0));
        let canonical = spec.to_json().to_string();
        assert!(canonical.contains(r#""max_error":0.25"#), "{canonical}");
        assert!(
            canonical.contains(r#""max_time_ms":1500"#),
            "{canonical}"
        );
        let back = ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
            .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // Budgets steer routing, not answers: the per-point cache form
        // drops them (and so collides with the unbudgeted sweep).
        let single = spec.at(&spec.expand()[0]);
        assert_eq!(single.max_error, None);
        assert_eq!(single.max_time_ms, None);
        let wire = single.to_json().to_string();
        assert!(!wire.contains("max_"), "{wire}");
        // Omitted budgets stay omitted, keeping pre-budget fixtures
        // byte-identical.
        let plain = ScenarioSpec::sim(512, Precision::Fp8, 4);
        assert!(!plain.to_json().to_string().contains("max_"));
    }

    #[test]
    fn bad_budgets_get_typed_errors() {
        // Wrong type: bad_request at decode.
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"max_error":"tight"}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("max_error"), "{err}");
        // Out of range: bad_range from validation.
        for line in [
            r#"{"n":512,"max_error":0}"#,
            r#"{"n":512,"max_error":-0.1}"#,
            r#"{"n":512,"max_time_ms":-5}"#,
        ] {
            let err =
                ScenarioSpec::from_json(&Json::parse(line).unwrap())
                    .unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRange, "{line}");
            assert!(err.message.contains("positive"), "{err}");
        }
    }

    #[test]
    fn precision_aliases_normalize_into_the_canonical_spelling() {
        let v = Json::parse(r#"{"n":256,"precision":"f8"}"#).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.precision, Precision::Fp8);
        assert!(spec.to_json().to_string().contains(r#""precision":"fp8""#));
    }

    #[test]
    fn plan_ask_defaults_its_objective() {
        let v = Json::parse(r#"{"ask":"plan","n":512}"#).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.objective, Some(Objective::LatencySensitive));
        assert_eq!(spec.iters, 100);
        let v =
            Json::parse(r#"{"ask":"sim","n":512,"objective":"latency"}"#)
                .unwrap();
        let err = ScenarioSpec::from_json(&v).unwrap_err();
        assert!(err.message.contains("only applies"), "{err}");
    }

    #[test]
    fn unknown_fields_in_spec_and_sweep_are_rejected() {
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"bogus":1}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownField);
        assert!(err.message.contains("bogus"));
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"sweep":{"bogus":[1]}}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownField);
    }

    #[test]
    fn sweep_cap_is_enforced_at_decode() {
        // 17 sizes x 16 stream counts = 272 > 256.
        let ns: Vec<String> =
            (1..=17).map(|i| (64 * i).to_string()).collect();
        let ss: Vec<String> = (1..=16).map(|i| i.to_string()).collect();
        let line = format!(
            r#"{{"n":512,"sweep":{{"n":[{}],"streams":[{}]}}}}"#,
            ns.join(","),
            ss.join(",")
        );
        let err =
            ScenarioSpec::from_json(&Json::parse(&line).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRange);
        assert!(err.message.contains("272"), "{err}");
        assert!(err.message.contains("256"), "{err}");
    }

    #[test]
    fn empty_sweep_axes_are_rejected() {
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"sweep":{"streams":[]}}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.message.contains("must not be empty"), "{err}");
    }

    #[test]
    fn expand_orders_points_n_major_iters_minor() {
        let mut spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
        spec.sweep.n = vec![256, 512];
        spec.sweep.streams = vec![1, 2];
        let points = spec.expand();
        assert_eq!(points.len(), 4);
        assert_eq!(
            points
                .iter()
                .map(|p| (p.n, p.streams))
                .collect::<Vec<_>>(),
            vec![(256, 1), (256, 2), (512, 1), (512, 2)]
        );
        // A sweep-less spec expands to its single base point.
        assert_eq!(
            ScenarioSpec::sim(512, Precision::Fp8, 4).expand(),
            vec![Point {
                n: 512,
                precision: Precision::Fp8,
                streams: 4,
                iters: 50,
                devices: 1,
                transform: Transform::Identity
            }]
        );
    }

    #[test]
    fn imbalanced_pair_pins_streams_and_owns_small_n() {
        let mut spec = ScenarioSpec::new(Ask::Sim);
        spec.shape = Shape::ImbalancedPair;
        spec.streams = 4;
        let err = spec.validate().unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRange);
        spec.streams = 2;
        spec.validate().unwrap();
        spec.sweep.streams = vec![1, 2];
        assert!(spec.validate().is_err());
        spec.sweep.streams.clear();

        let mut homog = ScenarioSpec::new(Ask::Sim);
        homog.small_n = Some(128);
        assert!(homog.validate().is_err());
    }

    #[test]
    fn kernel_sets_match_their_shapes() {
        let p = Point {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
            iters: 50,
            devices: 1,
            transform: Transform::Identity,
        };
        let homog = ScenarioSpec::sim(512, Precision::Fp8, 4);
        let ks = homog.kernels(&p);
        assert_eq!(ks.len(), 4);
        assert!(ks.iter().all(|k| k.m == 512 && k.iters == 50));

        let mut pair = ScenarioSpec::new(Ask::Sim);
        pair.shape = Shape::ImbalancedPair;
        pair.streams = 2;
        pair.n = 2048;
        let pp = Point { n: 2048, precision: Precision::Fp8, streams: 2,
                         iters: 50, devices: 1,
                         transform: Transform::Identity };
        let ks = pair.kernels(&pp);
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[0].m, 2048);
        assert_eq!(ks[1].m, 512, "default small_n is n/4");

        let mut mixed = ScenarioSpec::new(Ask::Sim);
        mixed.shape = Shape::MixedSparse;
        let ks = mixed.kernels(&p);
        assert_eq!(
            ks.iter().filter(|k| k.sparsity.is_sparse()).count(),
            2,
            "mixed_sparse alternates sparse/dense"
        );
        mixed.sparsity = SparsityMode::SparseBoth;
        let ks = mixed.kernels(&p);
        assert!(ks
            .iter()
            .filter(|k| k.sparsity.is_sparse())
            .all(|k| k.sparsity == SparsityMode::SparseBoth));
    }

    #[test]
    fn check_point_mirrors_v1_error_order() {
        let spec = ScenarioSpec::sim(512, Precision::Fp8, 32);
        let p = spec.expand()[0];
        let err = spec.check_point(&p).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRange);
        assert!(err.message.contains("streams must be in 1..=16 (got 32)"));
        // Sim checks n before streams.
        let spec = ScenarioSpec::sim(0, Precision::Fp8, 32);
        let err = spec.check_point(&spec.expand()[0]).unwrap_err();
        assert!(err.message.starts_with("n must be in"), "{err}");
        // Plan checks streams before n.
        let spec = ScenarioSpec::plan(
            Objective::LatencySensitive,
            99,
            0,
            Precision::Fp8,
        );
        let err = spec.check_point(&spec.expand()[0]).unwrap_err();
        assert!(err.message.starts_with("streams must be in"), "{err}");
    }

    #[test]
    fn single_point_cache_form_is_stable_under_at() {
        let mut spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
        spec.sweep.streams = vec![1, 4];
        let points = spec.expand();
        let single = spec.at(&points[1]);
        assert!(single.sweep.is_empty());
        assert_eq!(single.streams, 4);
        // The swept spec at its point equals the equivalent plain spec.
        assert_eq!(single, ScenarioSpec::sim(512, Precision::Fp8, 4));
    }

    #[test]
    fn device_set_canonicalizes_and_defaults_stay_omitted() {
        let v = Json::parse(
            r#"{"n":512,"shape":"data_parallel","device_set":{"devices":4,"topology":"ring"}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(
            spec.device_set,
            DeviceSet { devices: 4, topology: Topology::Ring }
        );
        let canonical = spec.to_json().to_string();
        assert!(
            canonical.contains(
                r#""device_set":{"devices":4,"topology":"ring"}"#
            ),
            "{canonical}"
        );
        let back =
            ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
                .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // The default set stays off the wire, keeping every pre-fabric
        // fixture byte-identical.
        let plain = ScenarioSpec::sim(512, Precision::Fp8, 4);
        assert!(!plain.to_json().to_string().contains("device_set"));
        // A single-device set with an explicit topology is preserved
        // as written (a devices sweep axis may still want the
        // topology) and is its own fixpoint.
        let line = r#"{"n":512,"shape":"halo","device_set":{"devices":1,"topology":"ring"}}"#;
        let spec =
            ScenarioSpec::from_json(&Json::parse(line).unwrap()).unwrap();
        assert_eq!(spec.device_set.topology, Topology::Ring);
        let canonical = spec.to_json().to_string();
        assert!(
            canonical.contains(
                r#""device_set":{"devices":1,"topology":"ring"}"#
            ),
            "{canonical}"
        );
        let back =
            ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
                .unwrap();
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // But the per-point cache form normalizes it away, so its
        // answer shares a cache entry with the plain spec.
        let single = spec.at(&spec.expand()[0]);
        assert!(single.device_set.is_default());
        assert!(!single.to_json().to_string().contains("device_set"));
    }

    #[test]
    fn device_set_validation_is_typed() {
        // Range: 0 and 5 devices are bad_range.
        for line in [
            r#"{"n":512,"shape":"data_parallel","device_set":{"devices":0}}"#,
            r#"{"n":512,"shape":"data_parallel","device_set":{"devices":5}}"#,
        ] {
            let err =
                ScenarioSpec::from_json(&Json::parse(line).unwrap())
                    .unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRange, "{line}");
            assert!(err.message.contains("device_set.devices"), "{err}");
        }
        // Unknown topology is bad_request naming the choices.
        let err = ScenarioSpec::from_json(
            &Json::parse(
                r#"{"n":512,"shape":"halo","device_set":{"devices":2,"topology":"torus"}}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("fully_connected|ring"), "{err}");
        // Multi-device wants a multi-device shape...
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"device_set":{"devices":2}}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("single-device"), "{err}");
        // ...and so does a devices sweep axis, even from a base of 1.
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"sweep":{"devices":[1,2,4]}}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // Multi-device shapes are sim-only.
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"ask":"plan","n":512,"shape":"pipeline"}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("only applies"), "{err}");
        // devices=1 on a multi-device shape is the scaling-curve
        // anchor and is fine.
        let v = Json::parse(r#"{"n":512,"shape":"data_parallel"}"#)
            .unwrap();
        ScenarioSpec::from_json(&v).unwrap();
    }

    #[test]
    fn devices_axis_sweeps_outermost_and_at_normalizes() {
        let v = Json::parse(
            r#"{"n":512,"shape":"data_parallel","device_set":{"topology":"ring"},"sweep":{"devices":[1,2,4],"streams":[1,2]}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let points = spec.expand();
        assert_eq!(points.len(), 6);
        assert_eq!(
            points
                .iter()
                .map(|p| (p.devices, p.streams))
                .collect::<Vec<_>>(),
            vec![(1, 1), (1, 2), (2, 1), (2, 2), (4, 1), (4, 2)]
        );
        // at() carries devices + topology into the cache form; the
        // devices=1 anchor normalizes to the default set so its wire
        // form matches a plain single-device spec.
        let d4 = spec.at(&points[4]);
        assert_eq!(
            d4.device_set,
            DeviceSet { devices: 4, topology: Topology::Ring }
        );
        let d1 = spec.at(&points[0]);
        assert!(d1.device_set.is_default());
        assert!(!d1.to_json().to_string().contains("device_set"));
        // The canonical sweep emits devices first (alphabetical).
        let wire = spec.to_json().to_string();
        assert!(
            wire.contains(r#""sweep":{"devices":[1,2,4],"streams":[1,2]}"#),
            "{wire}"
        );
    }

    #[test]
    fn multi_device_kernels_split_by_point_devices() {
        let v = Json::parse(
            r#"{"n":512,"shape":"pipeline","device_set":{"devices":4}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let p = spec.expand()[0];
        assert_eq!(p.devices, 4);
        let ks = spec.kernels(&p);
        assert!(ks.iter().all(|k| k.k == 128 && k.m == 512));

        let v = Json::parse(
            r#"{"n":512,"shape":"halo","device_set":{"devices":2}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let ks = spec.kernels(&spec.expand()[0]);
        assert!(ks.iter().all(|k| k.m == 256 && k.k == 512));

        let v = Json::parse(
            r#"{"n":512,"shape":"data_parallel","device_set":{"devices":4}}"#,
        )
        .unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let ks = spec.kernels(&spec.expand()[0]);
        assert!(ks.iter().all(|k| k.m == 512 && k.k == 512), "replica");
    }

    #[test]
    fn point_wire_form_omits_devices_when_single() {
        let p = Point {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
            iters: 50,
            devices: 1,
            transform: Transform::Identity,
        };
        let wire = p.to_json().to_string();
        assert!(!wire.contains("devices"), "{wire}");
        assert_eq!(Point::from_json(&Json::parse(&wire).unwrap(), "pt")
                       .unwrap(), p);
        let p4 = Point { devices: 4, ..p };
        let wire = p4.to_json().to_string();
        assert!(wire.starts_with(r#"{"devices":4,"#), "{wire}");
        assert_eq!(Point::from_json(&Json::parse(&wire).unwrap(), "pt")
                       .unwrap(), p4);
        // A non-identity transform rides the point wire form (last,
        // alphabetical) and roundtrips.
        let pt = Point {
            transform: Transform::Dilate(2),
            ..p
        };
        let wire = pt.to_json().to_string();
        assert!(wire.ends_with(r#""transform":"dilate:2"}"#), "{wire}");
        assert_eq!(Point::from_json(&Json::parse(&wire).unwrap(), "pt")
                       .unwrap(), pt);
    }

    // A two-stream trace: a big fp16 GEMM stream interleaved with
    // small fp8 launches.
    const TRACE_BODY: &str = r#"[
        {"kernel":"gemm","n":1024,"precision":"fp16","stream":0,"issue_ns":0},
        {"n":256,"stream":1,"issue_ns":500},
        {"kernel":"spmm","n":256,"stream":1,"issue_ns":2000},
        {"kernel":"gemm","n":1024,"precision":"fp16","stream":0,"issue_ns":2500}
    ]"#;

    fn trace_spec(extra: &str) -> Result<ScenarioSpec, ApiError> {
        let line =
            format!(r#"{{"shape":"trace","trace":{TRACE_BODY}{extra}}}"#);
        ScenarioSpec::from_json(&Json::parse(&line).unwrap())
    }

    #[test]
    fn trace_spec_normalizes_headline_fields_and_is_a_fixpoint() {
        let spec = trace_spec("").unwrap();
        // streams := stream count, n := max n, precision := dominant
        // (fp16 carries the 1024^3 launches), iters := 1.
        assert_eq!(spec.streams, 2);
        assert_eq!(spec.n, 1024);
        assert_eq!(spec.precision, Precision::F16);
        assert_eq!(spec.iters, 1);
        assert_eq!(spec.trace.len(), 4);
        let canonical = spec.to_json().to_string();
        assert!(canonical.contains(r#""shape":"trace""#), "{canonical}");
        assert!(
            canonical.contains(r#""trace":[{"issue_ns":0,"#),
            "{canonical}"
        );
        // identity transform stays off the wire.
        assert!(!canonical.contains("transform"), "{canonical}");
        let back =
            ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
                .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // Spelling the headline fields differently collides on the
        // same canonical form (one cache key per timeline).
        let respelled =
            trace_spec(r#","n":64,"precision":"fp8","streams":9"#)
                .unwrap();
        assert_eq!(respelled.to_json().to_string(), canonical);
        // The programmatic constructor is the decoder's twin.
        let built = ScenarioSpec::trace_replay(spec.trace.clone()).unwrap();
        assert_eq!(built.to_json().to_string(), canonical);
        // at() keeps the trace and the point's transform; the
        // identity point reproduces the spec itself.
        let points = spec.expand();
        assert_eq!(points.len(), 1);
        assert_eq!(spec.at(&points[0]), spec);
        spec.validated_points().unwrap();
    }

    #[test]
    fn trace_validation_is_typed() {
        // trace on a non-trace shape / shape trace without records.
        let err = ScenarioSpec::from_json(
            &Json::parse(&format!(
                r#"{{"n":512,"trace":{TRACE_BODY}}}"#
            ))
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("only applies"), "{err}");
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"shape":"trace"}"#).unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("requires"), "{err}");
        // transform needs shape trace.
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"n":512,"transform":"dilate:2"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.message.contains("only applies"), "{err}");
        // Unknown transform spellings name the accepted forms.
        let err = trace_spec(r#","transform":"reverse""#).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("sparsity_enable"), "{err}");
        // Trace shapes are sim-only and pin their own geometry.
        let err = trace_spec(r#","ask":"plan""#).unwrap_err();
        assert!(err.message.contains("only applies to ask"), "{err}");
        let err = trace_spec(r#","sweep":{"n":[256,512]}"#).unwrap_err();
        assert!(err.message.contains("transform"), "{err}");
        // Record defects keep the replay layer's error classes.
        let err = ScenarioSpec::from_json(
            &Json::parse(
                r#"{"shape":"trace","trace":[{"n":512,"stream":99,"issue_ns":0}]}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRange);
        assert!(err.message.contains("stream 99"), "{err}");
        let err = ScenarioSpec::from_json(
            &Json::parse(
                r#"{"shape":"trace","trace":[{"n":512,"stream":0,"issue_ns":100},{"n":512,"stream":0,"issue_ns":50}]}"#,
            )
            .unwrap(),
        )
        .unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("non-decreasing"), "{err}");
    }

    #[test]
    fn transform_axis_sweeps_innermost_and_rides_the_point() {
        let spec = trace_spec(
            r#","sweep":{"transform":["identity","precision_rewrite:fp8","stream_remap:1"]}"#,
        )
        .unwrap();
        let points = spec.expand();
        assert_eq!(points.len(), 3);
        assert_eq!(
            points.iter().map(|p| p.transform).collect::<Vec<_>>(),
            vec![
                Transform::Identity,
                Transform::PrecisionRewrite(Precision::Fp8),
                Transform::StreamRemap(1),
            ]
        );
        // The canonical sweep spells the axis canonically and the
        // whole spec is a fixpoint.
        let canonical = spec.to_json().to_string();
        assert!(
            canonical.contains(
                r#""sweep":{"transform":["identity","precision_rewrite:fp8","stream_remap:1"]}"#
            ),
            "{canonical}"
        );
        let back =
            ScenarioSpec::from_json(&Json::parse(&canonical).unwrap())
                .unwrap();
        assert_eq!(back.to_json().to_string(), canonical, "fixpoint");
        // Per-point cache forms differ exactly in their transform.
        let id = spec.at(&points[0]);
        let fp8 = spec.at(&points[1]);
        assert_eq!(id.transform, Transform::Identity);
        assert!(!id.to_json().to_string().contains("transform"));
        assert!(
            fp8.to_json()
                .to_string()
                .contains(r#""transform":"precision_rewrite:fp8""#),
        );
        spec.validated_points().unwrap();
    }

    #[test]
    fn trace_bounds_mirror_the_service_ranges() {
        use super::super::service::{SIM_STREAMS, SIZE_RANGE};
        use crate::replay::{MAX_TRACE_STREAMS, TRACE_N_RANGE};
        // The replay layer cannot import api; these pins keep its
        // mirrored bounds honest.
        assert_eq!(MAX_TRACE_STREAMS, SIM_STREAMS.1);
        assert_eq!(TRACE_N_RANGE, SIZE_RANGE);
    }

    #[test]
    fn spmm_mix_shape_alternates_kernel_classes_and_is_sim_only() {
        use crate::sim::kernel::KernelClass;
        let v = Json::parse(r#"{"n":512,"shape":"spmm_mix"}"#).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let ks = spec.kernels(&spec.expand()[0]);
        assert_eq!(ks.len(), 4);
        assert_eq!(
            ks.iter().filter(|k| k.class == KernelClass::Spmm).count(),
            2
        );
        let err = ScenarioSpec::from_json(
            &Json::parse(r#"{"ask":"plan","n":512,"shape":"spmm_mix"}"#)
                .unwrap(),
        )
        .unwrap_err();
        assert!(err.message.contains("only applies to ask"), "{err}");
    }
}
