//! The typed service API — the single front door to the whole system.
//!
//! Three pieces (DESIGN.md §6 is the wire-level spec):
//!
//! * [`protocol`] — versioned [`Request`]/[`Response`] enums with
//!   explicit [`ErrorCode`]s, their JSON wire encoding, and the legacy
//!   text-command shim.
//! * [`service`] — the [`Service`] core owning the shared config, the
//!   coordinator/engine construction, and the mpsc-isolated PJRT
//!   executor worker. `serve.rs` and `main.rs` are thin transports over
//!   it; neither holds business logic of its own.
//! * [`client`] — a blocking [`Client`] speaking the JSON-line framing
//!   with per-request ids, for tests, examples, and the `client`
//!   subcommand.
//!
//! Adding a request type means: one `Request`/`Response` variant pair,
//! one `Service::try_handle` arm, and (optionally) one legacy-shim arm —
//! every transport picks it up for free. Adding a transport means
//! speaking [`protocol`] at a `Service`; nothing else changes.

pub mod client;
pub mod protocol;
pub mod service;

pub use client::Client;
pub use protocol::{
    objective_name, parse_legacy, parse_objective, precision_wire_name,
    ApiError, ErrorCode, ExperimentInfo, LegacyCommand, PlanGroup, Request,
    Response, PROTOCOL_VERSION,
};
pub use service::{Service, POOL_STREAMS, SIM_STREAMS, SIZE_RANGE};
