//! The typed service API — the single front door to the whole system.
//!
//! Six pieces (DESIGN.md §6 is the wire-level spec; `docs/serving.md`
//! and `docs/scenarios.md` are the operator guides):
//!
//! * [`protocol`] — versioned [`Request`]/[`Response`] enums with
//!   explicit [`ErrorCode`]s, their JSON wire encoding (including the
//!   `batch` fan-out envelope, the `"cache":false` escape hatch, and
//!   the pushed `progress` frame), and the legacy text-command shim.
//! * [`scenario`] — the declarative [`ScenarioSpec`] surface
//!   (DESIGN.md §6.6): workload composition + sweep axes, canonical
//!   encoding, and compilation down to kernel sets. The v1
//!   `sim`/`plan`/`sparsity` requests are single-point special cases.
//! * [`job`] — the bounded async [`job::JobTable`] (DESIGN.md §6.7)
//!   behind `submit`/`job_status`/`job_result`/`job_cancel`, with
//!   per-point progress counters and watcher channels.
//! * [`service`] — the [`Service`] core owning the shared config, the
//!   result cache, the job workers, and the mpsc-isolated PJRT
//!   executor worker, dispatching every scenario point to a pluggable
//!   execution backend ([`crate::backend`], DESIGN.md §6.8: `des`
//!   replay vs `analytic` closed forms, selected by the `"backend"`
//!   envelope key / spec field and discovered via the `backends`
//!   request). `serve.rs` and `main.rs` are thin transports over it;
//!   neither holds business logic of its own.
//! * [`cache`] — the canonical-key bounded-LRU result cache, keyed at
//!   sweep-point granularity for scenario-backed requests, with
//!   hit/miss/eviction counters surfaced by the `stats` request.
//! * [`client`] — a blocking [`Client`] speaking the JSON-line framing
//!   with per-request ids, connect/read timeouts, and job helpers
//!   (`submit`/`wait_job`/`submit_and_wait` with progress callbacks).
//!
//! Adding a request type means: one `Request`/`Response` variant pair,
//! one `Service::try_handle` arm, and (optionally) one legacy-shim arm —
//! every transport picks it up for free. Adding a transport means
//! speaking [`protocol`] at a `Service`; nothing else changes.
//!
//! # Quickstart (in-process)
//!
//! The service works without any socket — the CLI subcommands use it
//! exactly like this:
//!
//! ```
//! use mi300a_char::api::{Request, Response, Service};
//! use mi300a_char::config::Config;
//!
//! let svc = Service::new(Config::mi300a());
//! match svc.handle(&Request::ListExperiments) {
//!     Response::Experiments { experiments } => {
//!         assert!(experiments.iter().any(|e| e.id == "fig4"));
//!     }
//!     other => panic!("unexpected response: {other:?}"),
//! }
//! ```
//!
//! # Quickstart (served)
//!
//! The same requests over TCP, through the typed [`Client`] (see
//! `examples/quickstart.rs` for the full version):
//!
//! ```no_run
//! use mi300a_char::api::{Client, Request, Response};
//! use mi300a_char::config::Config;
//! use mi300a_char::isa::Precision;
//!
//! std::thread::spawn(|| {
//!     mi300a_char::serve::serve(Config::mi300a(), "127.0.0.1:7300", Some(1))
//! });
//! let mut client = Client::connect_retry("127.0.0.1:7300", 200)?;
//! // A batch answers N sub-requests in one envelope; repeats are
//! // served from the result cache without re-running the DES engine.
//! let responses = client.batch(&[
//!     Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
//!     Request::Sim { n: 512, precision: Precision::Fp8, streams: 4 },
//!     Request::Stats,
//! ])?;
//! if let Response::Stats { cache, .. } = &responses[2] {
//!     assert_eq!(cache.hits, 1, "second item hit the cache");
//! }
//! # Ok::<(), std::io::Error>(())
//! ```

pub mod cache;
pub mod client;
pub mod job;
pub mod protocol;
pub mod scenario;
pub mod service;

pub use cache::{CachePolicy, CacheStats, ResultCache};
pub use client::{Client, OverloadedRetry, DEFAULT_TIMEOUT};
pub use job::{JobLimits, JobState, JobView};
pub use protocol::{
    objective_name, parse_legacy, parse_objective, precision_wire_name,
    ApiError, BackendInfo, ClusterStats, ErrorCode, ExperimentInfo,
    LegacyCommand, PlanGroup, Request, RequestEnvelope, Response,
    CLUSTER_STAT_FIELDS, MAX_BATCH_ITEMS, PROTOCOL_VERSION,
};
pub use scenario::{
    Ask, Point, PointResult, ScenarioSpec, Shape, Sweep, ITERS_RANGE,
    MAX_SWEEP_POINTS,
};
pub use service::{Service, POOL_STREAMS, SIM_STREAMS, SIZE_RANGE};
