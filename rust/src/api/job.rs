//! The async job subsystem behind the service (DESIGN.md §6.7).
//!
//! Scenario sweeps are long-running; a `submit` request must not block
//! its connection. The [`JobTable`] is a bounded in-process queue:
//! submissions beyond `max_queued` are refused with a typed
//! `overloaded` error (never silently dropped), at most `max_running`
//! jobs execute concurrently (the service spawns that many worker
//! threads), and finished jobs are retained up to `max_finished` before
//! the oldest results are evicted (querying an evicted id is
//! `unknown_job`).
//!
//! Lifecycle (observable through `job_status`):
//!
//! ```text
//!   queued ──► running ──► done
//!     │           │   └──► failed
//!     └───────────┴──────► cancelled     (job_cancel; mid-sweep the
//!                                         flag is honored between
//!                                         points)
//! ```
//!
//! Progress: every job carries `completed`/`total` sweep-point
//! counters. Watchers (the serve transport's progress push) receive a
//! [`JobView`] snapshot at registration — so at least one frame is
//! always pushed, however fast the job — then one on the
//! queued→running transition, one per completed point, and a final one
//! at the terminal state, after which the channel closes (an N-point
//! job pushes N+3 frames). Budgeted `auto` jobs additionally run a
//! background refinement pass after every point is answered
//! (DESIGN.md §6.10): each DES re-run of a low-confidence point bumps
//! the `refined` counter and frames watchers again, so such a job
//! pushes N+3+R frames (`refined` is carried on the wire only when
//! nonzero, keeping unrefined frames byte-identical).

use super::protocol::{ApiError, ErrorCode, Response};
use super::scenario::ScenarioSpec;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{mpsc, Condvar, Mutex, MutexGuard};

/// Job lifecycle states (wire spellings via [`JobState::as_str`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Cancelled,
    Failed,
}

impl JobState {
    pub const ALL: [JobState; 5] = [
        JobState::Queued,
        JobState::Running,
        JobState::Done,
        JobState::Cancelled,
        JobState::Failed,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Option<JobState> {
        JobState::ALL.iter().copied().find(|x| x.as_str() == s)
    }

    /// Whether the state is final (no further transitions or frames).
    pub fn terminal(self) -> bool {
        matches!(
            self,
            JobState::Done | JobState::Cancelled | JobState::Failed
        )
    }
}

/// A point-in-time job snapshot: what `submit`/`job_status`/
/// `job_cancel` responses and `progress` frames carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobView {
    /// Server-assigned job id.
    pub job: u64,
    pub state: JobState,
    /// Sweep points finished so far.
    pub completed: u64,
    /// Low-confidence points re-answered on the DES by the refinement
    /// pass of a budgeted `auto` job (0 everywhere else).
    pub refined: u64,
    /// Total sweep points.
    pub total: u64,
}

/// Sizing of the job table. `max_running` worker threads are spawned by
/// the service (0 means jobs queue but never run — test-only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobLimits {
    /// Jobs executing concurrently (worker thread count).
    pub max_running: usize,
    /// Queued (not yet running) jobs beyond which `submit` answers
    /// `overloaded`.
    pub max_queued: usize,
    /// Terminal jobs retained for `job_result`; the oldest beyond this
    /// are evicted.
    pub max_finished: usize,
}

impl Default for JobLimits {
    fn default() -> JobLimits {
        JobLimits { max_running: 2, max_queued: 16, max_finished: 64 }
    }
}

/// A progress-frame sink registered at submit time. Two transports
/// exist: the thread-per-connection path drains a [`Watcher::Channel`]
/// receiver on a dedicated pusher thread, while the epoll reactor
/// registers a [`Watcher::Callback`] that enqueues the frame to the
/// event loop (no thread per watched submit).
pub enum Watcher {
    /// Buffered channel; the receiver side is handed to the submitter.
    Channel(mpsc::Sender<JobView>),
    /// Direct callback, invoked under the job-table lock — it must be
    /// cheap and non-blocking (the reactor's is a queue push plus an
    /// eventfd wake).
    Callback(Box<dyn Fn(JobView) + Send>),
}

impl Watcher {
    fn send(&self, view: JobView) {
        match self {
            Watcher::Channel(tx) => {
                let _ = tx.send(view);
            }
            Watcher::Callback(f) => f(view),
        }
    }
}

struct JobEntry {
    spec: ScenarioSpec,
    /// The submit envelope's `cache` flag: `false` makes every point
    /// run cold (the measurement escape hatch, same as sync requests).
    use_cache: bool,
    state: JobState,
    completed: u64,
    refined: u64,
    total: u64,
    cancel_requested: bool,
    result: Option<Result<Response, ApiError>>,
    watchers: Vec<Watcher>,
}

impl JobEntry {
    fn view(&self, id: u64) -> JobView {
        JobView {
            job: id,
            state: self.state,
            completed: self.completed,
            refined: self.refined,
            total: self.total,
        }
    }

    /// Best-effort frame to every watcher (a gone watcher is dropped at
    /// the terminal broadcast, not here — Vec retain would reorder
    /// nothing but costs a scan per point).
    fn notify(&self, id: u64) {
        for w in &self.watchers {
            let _ = w.send(self.view(id));
        }
    }
}

#[derive(Default)]
struct Inner {
    jobs: BTreeMap<u64, JobEntry>,
    queue: VecDeque<u64>,
    finished: VecDeque<u64>,
    next_id: u64,
    shutdown: bool,
}

/// Bounded, thread-safe job table. The service owns one behind an
/// `Arc`; worker threads block on [`JobTable::next_job`].
pub struct JobTable {
    limits: JobLimits,
    inner: Mutex<Inner>,
    cond: Condvar,
}

impl JobTable {
    pub fn new(limits: JobLimits) -> JobTable {
        JobTable {
            limits,
            inner: Mutex::new(Inner { next_id: 1, ..Inner::default() }),
            cond: Condvar::new(),
        }
    }

    pub fn limits(&self) -> JobLimits {
        self.limits
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue a validated spec expanding to `total` points. `watch`
    /// registers a progress receiver atomically with the enqueue (its
    /// first frame is the queued snapshot, so a watcher never misses
    /// every frame even if the job finishes instantly); `use_cache:
    /// false` carries the submit envelope's cache bypass to the
    /// workers.
    pub fn submit(
        &self,
        spec: ScenarioSpec,
        total: u64,
        watch: bool,
        use_cache: bool,
    ) -> Result<(JobView, Option<mpsc::Receiver<JobView>>), ApiError> {
        if watch {
            let (tx, rx) = mpsc::channel();
            let view = self.submit_with(
                spec,
                total,
                Some(Watcher::Channel(tx)),
                use_cache,
            )?;
            Ok((view, Some(rx)))
        } else {
            let view = self.submit_with(spec, total, None, use_cache)?;
            Ok((view, None))
        }
    }

    /// [`JobTable::submit`] with an explicit frame sink: the epoll
    /// reactor registers a [`Watcher::Callback`] here instead of a
    /// channel + pusher thread. The watcher receives the queued
    /// snapshot atomically with the enqueue, exactly like the channel
    /// path.
    pub fn submit_with(
        &self,
        spec: ScenarioSpec,
        total: u64,
        watcher: Option<Watcher>,
        use_cache: bool,
    ) -> Result<JobView, ApiError> {
        let mut g = self.lock();
        let inner = &mut *g;
        if inner.shutdown {
            return Err(ApiError::new(
                ErrorCode::Runtime,
                "job table is shutting down",
            ));
        }
        if inner.queue.len() >= self.limits.max_queued {
            return Err(ApiError::new(
                ErrorCode::Overloaded,
                format!(
                    "job queue is full ({} queued, cap {}); retry after a \
                     job finishes",
                    inner.queue.len(),
                    self.limits.max_queued
                ),
            ));
        }
        let id = inner.next_id;
        inner.next_id += 1;
        let mut entry = JobEntry {
            spec,
            use_cache,
            state: JobState::Queued,
            completed: 0,
            refined: 0,
            total,
            cancel_requested: false,
            result: None,
            watchers: Vec::new(),
        };
        let view = entry.view(id);
        if let Some(w) = watcher {
            w.send(view);
            entry.watchers.push(w);
        }
        inner.jobs.insert(id, entry);
        inner.queue.push_back(id);
        self.cond.notify_one();
        Ok(view)
    }

    /// Worker side: block until a job is queued, mark it running, and
    /// hand its spec (plus its cache flag) over. `None` means the table
    /// shut down.
    pub fn next_job(&self) -> Option<(u64, ScenarioSpec, bool)> {
        let mut g = self.lock();
        loop {
            {
                let inner = &mut *g;
                if inner.shutdown {
                    return None;
                }
                if let Some(id) = inner.queue.pop_front() {
                    if let Some(e) = inner.jobs.get_mut(&id) {
                        e.state = JobState::Running;
                        e.notify(id);
                        return Some((id, e.spec.clone(), e.use_cache));
                    }
                    continue;
                }
            }
            g = self.cond.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Worker side: may the running job proceed to its next point?
    pub fn should_continue(&self, id: u64) -> bool {
        let g = self.lock();
        if g.shutdown {
            return false;
        }
        g.jobs.get(&id).map_or(false, |e| !e.cancel_requested)
    }

    /// Worker side: one more point finished; frames watchers. Returns
    /// whether the job may continue.
    pub fn point_done(&self, id: u64) -> bool {
        let mut g = self.lock();
        let inner = &mut *g;
        let shutdown = inner.shutdown;
        match inner.jobs.get_mut(&id) {
            Some(e) => {
                e.completed += 1;
                e.notify(id);
                !e.cancel_requested && !shutdown
            }
            None => false,
        }
    }

    /// Worker side: one low-confidence point re-answered on the DES by
    /// the refinement pass; frames watchers (the frame's `completed`
    /// already equals `total` — only `refined` moves). Returns whether
    /// refinement may continue. Never touches `completed`.
    pub fn point_refined(&self, id: u64) -> bool {
        let mut g = self.lock();
        let inner = &mut *g;
        let shutdown = inner.shutdown;
        match inner.jobs.get_mut(&id) {
            Some(e) => {
                e.refined += 1;
                e.notify(id);
                !e.cancel_requested && !shutdown
            }
            None => false,
        }
    }

    /// Worker side: terminal transition with the job's outcome.
    pub fn finish(&self, id: u64, result: Result<Response, ApiError>) {
        let state = if result.is_err() {
            JobState::Failed
        } else {
            JobState::Done
        };
        let mut g = self.lock();
        let inner = &mut *g;
        if let Some(e) = inner.jobs.get_mut(&id) {
            e.state = state;
            e.result = Some(result);
        }
        Self::seal(inner, id, self.limits);
    }

    /// Worker side: the cancel flag (or shutdown) was honored mid-sweep.
    pub fn mark_cancelled(&self, id: u64) {
        let mut g = self.lock();
        let inner = &mut *g;
        if let Some(e) = inner.jobs.get_mut(&id) {
            e.state = JobState::Cancelled;
        }
        Self::seal(inner, id, self.limits);
    }

    /// Terminal bookkeeping: final frame, watcher channel closure,
    /// retention eviction.
    fn seal(g: &mut Inner, id: u64, limits: JobLimits) {
        if let Some(e) = g.jobs.get_mut(&id) {
            e.notify(id);
            e.watchers.clear();
        }
        g.finished.push_back(id);
        while g.finished.len() > limits.max_finished.max(1) {
            if let Some(old) = g.finished.pop_front() {
                g.jobs.remove(&old);
            }
        }
    }

    /// Request a cancel. Queued jobs cancel immediately; running jobs
    /// have the flag honored between sweep points; terminal jobs are
    /// untouched. Returns the post-action snapshot.
    pub fn cancel(&self, id: u64) -> Result<JobView, ApiError> {
        let mut g = self.lock();
        let inner = &mut *g;
        let entry =
            inner.jobs.get_mut(&id).ok_or_else(|| unknown_job(id))?;
        match entry.state {
            JobState::Queued => {
                entry.state = JobState::Cancelled;
                entry.cancel_requested = true;
                let view = entry.view(id);
                inner.queue.retain(|&q| q != id);
                Self::seal(inner, id, self.limits);
                Ok(view)
            }
            JobState::Running => {
                entry.cancel_requested = true;
                Ok(entry.view(id))
            }
            _ => Ok(entry.view(id)),
        }
    }

    /// Point-in-time snapshot for `job_status`.
    pub fn status(&self, id: u64) -> Result<JobView, ApiError> {
        let g = self.lock();
        g.jobs
            .get(&id)
            .map(|e| e.view(id))
            .ok_or_else(|| unknown_job(id))
    }

    /// The finished result for `job_result`. Non-terminal (and
    /// cancelled) jobs answer `not_ready`; failed jobs answer their
    /// stored error.
    pub fn result(&self, id: u64) -> Result<Response, ApiError> {
        let g = self.lock();
        let e = g.jobs.get(&id).ok_or_else(|| unknown_job(id))?;
        match e.state {
            JobState::Done => match &e.result {
                Some(Ok(resp)) => Ok(resp.clone()),
                _ => Err(ApiError::new(
                    ErrorCode::Runtime,
                    format!("job {id} finished without a result"),
                )),
            },
            JobState::Failed => match &e.result {
                Some(Err(err)) => Err(err.clone()),
                _ => Err(ApiError::new(
                    ErrorCode::Runtime,
                    format!("job {id} failed without a recorded error"),
                )),
            },
            JobState::Cancelled => Err(ApiError::new(
                ErrorCode::NotReady,
                format!(
                    "job {id} was cancelled after {}/{} points",
                    e.completed, e.total
                ),
            )),
            JobState::Queued | JobState::Running => Err(ApiError::new(
                ErrorCode::NotReady,
                format!(
                    "job {id} is {} ({}/{} points done)",
                    e.state.as_str(),
                    e.completed,
                    e.total
                ),
            )),
        }
    }

    /// Stop handing out work and wake every blocked worker; running
    /// jobs observe the flag between points and cancel.
    pub fn shutdown(&self) {
        let mut g = self.lock();
        g.shutdown = true;
        drop(g);
        self.cond.notify_all();
    }
}

fn unknown_job(id: u64) -> ApiError {
    ApiError::new(
        ErrorCode::UnknownJob,
        format!("unknown job {id} (finished jobs are retained, then \
                 evicted oldest-first)"),
    )
}

#[cfg(test)]
mod tests {
    use super::super::scenario::ScenarioSpec;
    use super::super::scenario::Ask;
    use super::*;

    fn table(max_queued: usize) -> JobTable {
        JobTable::new(JobLimits {
            max_running: 0,
            max_queued,
            max_finished: 4,
        })
    }

    fn spec() -> ScenarioSpec {
        ScenarioSpec::new(Ask::Sim)
    }

    #[test]
    fn queue_cap_is_a_typed_overloaded_error() {
        let t = table(2);
        let (a, _) = t.submit(spec(), 1, false, true).unwrap();
        let (b, _) = t.submit(spec(), 1, false, true).unwrap();
        assert_eq!((a.job, b.job), (1, 2));
        assert_eq!(a.state, JobState::Queued);
        let err = t.submit(spec(), 1, false, true).unwrap_err();
        assert_eq!(err.code, ErrorCode::Overloaded);
        assert!(err.message.contains("cap 2"), "{err}");
    }

    #[test]
    fn queued_jobs_cancel_immediately_and_leave_the_queue() {
        let t = table(4);
        let (v, _) = t.submit(spec(), 3, false, true).unwrap();
        let after = t.cancel(v.job).unwrap();
        assert_eq!(after.state, JobState::Cancelled);
        assert_eq!(t.status(v.job).unwrap().state, JobState::Cancelled);
        let err = t.result(v.job).unwrap_err();
        assert_eq!(err.code, ErrorCode::NotReady);
        assert!(err.message.contains("cancelled"), "{err}");
        // The queue slot is freed for new work.
        let (w, _) = t.submit(spec(), 1, false, true).unwrap();
        assert_eq!(w.job, v.job + 1);
    }

    #[test]
    fn unknown_ids_and_unfinished_results_are_typed() {
        let t = table(4);
        assert_eq!(t.status(99).unwrap_err().code, ErrorCode::UnknownJob);
        assert_eq!(t.cancel(99).unwrap_err().code, ErrorCode::UnknownJob);
        let (v, _) = t.submit(spec(), 2, false, true).unwrap();
        let err = t.result(v.job).unwrap_err();
        assert_eq!(err.code, ErrorCode::NotReady);
        assert!(err.message.contains("queued"), "{err}");
    }

    #[test]
    fn watcher_gets_the_snapshot_frame_then_lifecycle_frames() {
        let t = table(4);
        let (v, rx) = t.submit(spec(), 2, true, true).unwrap();
        let rx = rx.unwrap();
        assert_eq!(rx.recv().unwrap().state, JobState::Queued);
        // Drive the worker side by hand (max_running 0 spawns none).
        let (id, _spec, use_cache) = t.next_job().unwrap();
        assert!(use_cache);
        assert_eq!(id, v.job);
        assert_eq!(rx.recv().unwrap().state, JobState::Running);
        assert!(t.point_done(id));
        let frame = rx.recv().unwrap();
        assert_eq!((frame.completed, frame.total), (1, 2));
        assert!(t.point_done(id));
        t.finish(id, Ok(Response::Scenario { points: vec![] }));
        // Remaining frames end with the terminal one, then the channel
        // closes.
        let mut last = frame;
        while let Ok(f) = rx.recv() {
            last = f;
        }
        assert_eq!(last.state, JobState::Done);
        assert_eq!(last.completed, 2);
        assert!(t.result(id).is_ok());
    }

    #[test]
    fn callback_watcher_sees_the_same_frame_sequence_as_a_channel() {
        let t = table(4);
        let frames = std::sync::Arc::new(Mutex::new(Vec::new()));
        let sink = std::sync::Arc::clone(&frames);
        let v = t
            .submit_with(
                spec(),
                2,
                Some(Watcher::Callback(Box::new(move |f| {
                    sink.lock().unwrap().push(f)
                }))),
                true,
            )
            .unwrap();
        let (id, _, _) = t.next_job().unwrap();
        assert_eq!(id, v.job);
        assert!(t.point_done(id));
        assert!(t.point_done(id));
        t.finish(id, Ok(Response::Scenario { points: vec![] }));
        let got = frames.lock().unwrap().clone();
        // N+3 frames: queued snapshot, running, one per point, terminal.
        assert_eq!(got.len(), 5);
        assert_eq!(got[0].state, JobState::Queued);
        assert_eq!(got[1].state, JobState::Running);
        assert_eq!((got[2].completed, got[3].completed), (1, 2));
        let last = got.last().unwrap();
        assert_eq!(last.state, JobState::Done);
        assert_eq!(last.completed, 2);
    }

    #[test]
    fn refinement_frames_move_refined_without_touching_completed() {
        let t = table(4);
        let (v, rx) = t.submit(spec(), 1, true, true).unwrap();
        let rx = rx.unwrap();
        let (id, _, _) = t.next_job().unwrap();
        assert_eq!(id, v.job);
        assert!(t.point_done(id));
        // The refinement pass re-answers the point on the DES.
        assert!(t.point_refined(id));
        t.finish(id, Ok(Response::Scenario { points: vec![] }));
        let frames: Vec<JobView> = rx.iter().collect();
        // queued, running, point, refined, terminal.
        assert_eq!(frames.len(), 5);
        let refined = frames[3];
        assert_eq!((refined.completed, refined.refined, refined.total),
                   (1, 1, 1));
        assert_eq!(t.status(id).unwrap().refined, 1);
        // Unrefined frames all carry refined == 0.
        assert!(frames[..3].iter().all(|f| f.refined == 0));
    }

    #[test]
    fn finished_retention_evicts_oldest() {
        let t = table(16); // max_finished 4
        let mut ids = Vec::new();
        for _ in 0..6 {
            let (v, _) = t.submit(spec(), 1, false, true).unwrap();
            let (id, _, _) = t.next_job().unwrap();
            assert_eq!(id, v.job);
            t.finish(id, Ok(Response::Scenario { points: vec![] }));
            ids.push(id);
        }
        assert_eq!(
            t.status(ids[0]).unwrap_err().code,
            ErrorCode::UnknownJob
        );
        assert_eq!(
            t.status(ids[1]).unwrap_err().code,
            ErrorCode::UnknownJob
        );
        assert!(t.status(ids[5]).is_ok());
    }

    #[test]
    fn shutdown_unblocks_workers() {
        let t = std::sync::Arc::new(table(4));
        let t2 = std::sync::Arc::clone(&t);
        let h = std::thread::spawn(move || t2.next_job());
        std::thread::sleep(std::time::Duration::from_millis(20));
        t.shutdown();
        assert_eq!(h.join().unwrap(), None);
        assert_eq!(
            t.submit(spec(), 1, false, true).unwrap_err().code,
            ErrorCode::Runtime
        );
    }
}
