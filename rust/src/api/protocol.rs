//! The versioned request/response wire protocol (DESIGN.md §6).
//!
//! One typed surface for every transport: the TCP serve loop, the CLI
//! subcommands, and [`super::Client`] all speak [`Request`] and
//! [`Response`]. On the wire a message is a single JSON object per line:
//!
//! ```text
//! {"v":1,"id":7,"type":"sim","n":512,"precision":"fp8","streams":4}
//! {"v":1,"id":7,"type":"sim","fairness":0.61,"l2_miss":0.18,...}
//! ```
//!
//! Envelope rules (DESIGN.md §6.1):
//! * `"v"` is mandatory and must equal [`PROTOCOL_VERSION`]; anything
//!   else is rejected with [`ErrorCode::BadVersion`]. Adding a field is
//!   a version bump; this module rejects unknown fields precisely so
//!   that a v2 request can never be silently half-understood by a v1
//!   server.
//! * `"id"` is an optional nonnegative integer echoed verbatim on the
//!   response, so clients can pipeline requests on one connection.
//! * `"type"` selects the variant; remaining keys are the payload.
//!   Unknown keys are rejected with [`ErrorCode::UnknownField`].
//!
//! Errors are themselves typed responses (`"type":"error"`) carrying a
//! machine-readable [`ErrorCode`] plus a human message under `"error"`.
//!
//! Two further envelope-level features (DESIGN.md §6.5):
//!
//! * `"cache":false` (optional, default `true`) bypasses the service's
//!   result cache for this request — the measurement-run escape hatch.
//!   It is a request-envelope key like `"id"`, decoded into
//!   [`RequestEnvelope`]; responses never carry it.
//! * `"type":"batch"` carries an ordered `"items"` array of
//!   envelope-less sub-requests and answers them in one
//!   `"type":"batch"` response envelope, item `k` answering request
//!   `k`. Items may not nest another batch and share the result cache
//!   within the one call.
//!
//! The scenario/job amendment (DESIGN.md §6.6–§6.7, landed under the
//! §6.4 pre-1.0 rule) adds the declarative `scenario` request (a
//! [`ScenarioSpec`] sweep answered point-by-point), the async job
//! surface (`submit`/`job_status`/`job_result`/`job_cancel`), and the
//! pushed `progress` frame — an interleaved line keyed by the
//! submitting request's `id`, which is what keeps the one-line-per-
//! request pipelining contract intact for everything else.
//!
//! The backend amendment (DESIGN.md §6.8, same pre-1.0 rule) adds the
//! optional `"backend"` request-envelope key (also a ScenarioSpec
//! field) selecting which execution backend answers scenario-backed
//! requests, the `backends` capability-discovery request, the typed
//! `unknown_backend` / `unsupported_by_backend` errors, and per-backend
//! `engine_runs_<id>` counters on `stats`. Omitting `backend` keeps
//! every scenario-backed request, spec, and response byte-identical to
//! the pre-backend protocol; the introspection responses (`stats`,
//! `list_experiments`) gained fields under the §6.4 pre-1.0 rule, like
//! every amendment before this one.
//!
//! The legacy whitespace text commands (`SIM`/`PLAN`/`SPARSITY`/`RUN`/
//! `QUIT`) survive as [`parse_legacy`], a shim that desugars a text line
//! into the same typed [`Request`]s — both framings produce
//! byte-identical response lines (enforced by
//! `tests/serve_integration.rs`).

use super::cache::CacheStats;
use super::job::{JobState, JobView};
use super::scenario::{self, Point, PointResult, ScenarioSpec};
use crate::backend::BackendId;
use crate::coordinator::Objective;
use crate::isa::Precision;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Wire-format version. Bump on any schema change; servers reject every
/// other version with [`ErrorCode::BadVersion`] (DESIGN.md §6.4).
pub const PROTOCOL_VERSION: u64 = 1;

/// Maximum items in one batch request (a bigger batch is a
/// [`ErrorCode::BadRange`] error, not a partially-served one). Enforced
/// at decode time — before any per-item work — and again by the service
/// for programmatically built batches.
pub const MAX_BATCH_ITEMS: usize = 256;

/// Machine-readable error categories (DESIGN.md §6.3). `as_str` gives
/// the wire spelling; the set is closed per protocol version.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// `"v"` missing or not [`PROTOCOL_VERSION`].
    BadVersion,
    /// Malformed envelope or payload (missing/mistyped field, bad JSON).
    BadRequest,
    /// `"type"` (or legacy command word) is not part of this protocol.
    UnknownType,
    /// A payload key this protocol version does not define.
    UnknownField,
    /// A well-typed value outside its accepted range.
    BadRange,
    /// `repro` asked for an experiment id the registry does not have.
    UnknownExperiment,
    /// `run` asked for an artifact entry the manifest does not have.
    UnknownEntry,
    /// The executor/runtime failed (missing artifacts, stub build, ...).
    Runtime,
    /// `submit` refused: the bounded job queue is full (DESIGN.md §6.7).
    Overloaded,
    /// A `job_*` request named an id the table does not hold (never
    /// assigned, or evicted after finishing).
    UnknownJob,
    /// `job_result` asked for a job that has not finished (or was
    /// cancelled mid-sweep).
    NotReady,
    /// A `"backend"` key (envelope or ScenarioSpec) named an id the
    /// backend registry does not have (DESIGN.md §6.8).
    UnknownBackend,
    /// The selected backend is registered but cannot answer this
    /// ask/shape combination (see `Request::Backends` for the
    /// capability table).
    UnsupportedByBackend,
}

impl ErrorCode {
    /// Every code, for exhaustive protocol tests.
    pub const ALL: [ErrorCode; 13] = [
        ErrorCode::BadVersion,
        ErrorCode::BadRequest,
        ErrorCode::UnknownType,
        ErrorCode::UnknownField,
        ErrorCode::BadRange,
        ErrorCode::UnknownExperiment,
        ErrorCode::UnknownEntry,
        ErrorCode::Runtime,
        ErrorCode::Overloaded,
        ErrorCode::UnknownJob,
        ErrorCode::NotReady,
        ErrorCode::UnknownBackend,
        ErrorCode::UnsupportedByBackend,
    ];

    /// The stable wire spelling (e.g. `bad_range`).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadVersion => "bad_version",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownType => "unknown_type",
            ErrorCode::UnknownField => "unknown_field",
            ErrorCode::BadRange => "bad_range",
            ErrorCode::UnknownExperiment => "unknown_experiment",
            ErrorCode::UnknownEntry => "unknown_entry",
            ErrorCode::Runtime => "runtime",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::NotReady => "not_ready",
            ErrorCode::UnknownBackend => "unknown_backend",
            ErrorCode::UnsupportedByBackend => "unsupported_by_backend",
        }
    }

    /// Inverse of [`ErrorCode::as_str`].
    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.as_str() == s)
    }
}

/// A typed protocol error: code + human-readable message. Transports
/// serialize it as a `Response::Error`.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
}

impl ApiError {
    /// An error with an explicit [`ErrorCode`].
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError { code, message: message.into() }
    }

    /// Shorthand for the most common code, [`ErrorCode::BadRequest`].
    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, message)
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code.as_str(), self.message)
    }
}

impl std::error::Error for ApiError {}

/// Canonical lowercase wire spelling for a precision; `Precision::parse`
/// accepts it back, so precision fields round-trip byte-identically.
pub fn precision_wire_name(p: Precision) -> &'static str {
    match p {
        Precision::F64 => "fp64",
        Precision::F32 => "fp32",
        Precision::F16 => "fp16",
        Precision::Bf16 => "bf16",
        Precision::Fp8 => "fp8",
        Precision::Bf8 => "bf8",
    }
}

/// Wire spelling of a coordinator objective.
pub fn objective_name(o: Objective) -> &'static str {
    match o {
        Objective::LatencySensitive => "latency",
        Objective::ThroughputOriented => "throughput",
        Objective::StrictIsolation => "isolation",
    }
}

pub fn parse_objective(s: &str) -> Option<Objective> {
    match s {
        "latency" => Some(Objective::LatencySensitive),
        "throughput" => Some(Objective::ThroughputOriented),
        "isolation" => Some(Objective::StrictIsolation),
        _ => None,
    }
}

/// Envelope options decoded alongside a [`Request`]: the pipelining
/// `id` (echoed on the response), the `cache` escape hatch
/// (`"cache":false` bypasses the service's result cache for this one
/// request), and the `backend` selector (DESIGN.md §6.8 — which
/// execution backend answers the scenario-backed requests; `None`
/// means the serving instance's default). Absent keys take the
/// defaults (`id: None`, `cache: true`, `backend: None`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestEnvelope {
    /// Client-chosen request id, echoed verbatim on the response.
    pub id: Option<u64>,
    /// Whether the service may answer from (and fill) its result cache.
    pub cache: bool,
    /// Execution backend for scenario-backed requests
    /// (sim/plan/sparsity/scenario/submit); a typed error on anything
    /// else. `None` = the serving instance's default backend.
    pub backend: Option<BackendId>,
}

impl Default for RequestEnvelope {
    fn default() -> RequestEnvelope {
        RequestEnvelope { id: None, cache: true, backend: None }
    }
}

/// A typed request — the single front door to the system (DESIGN.md
/// §6.2 lists the payload schema per variant).
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Simulate `streams` concurrent FP-`precision` GEMMs of size `n`.
    Sim { n: usize, precision: Precision, streams: usize },
    /// Coordinator execution plan for a pool of `streams` GEMMs at
    /// `precision` (the legacy text shim defaults it to FP8).
    Plan {
        objective: Objective,
        streams: usize,
        n: usize,
        precision: Precision,
    },
    /// Context-dependent 2:4 sparsity decision + modeled speedups.
    Sparsity { n: usize, streams: usize },
    /// Execute one AOT'd artifact through the PJRT executor worker.
    Run { entry: String },
    /// Regenerate one paper table/figure (DESIGN.md §5 ids).
    Repro { experiment: String },
    /// Enumerate the experiment registry.
    ListExperiments,
    /// Dump the service's active configuration.
    Config,
    /// An ordered list of sub-requests answered in one envelope. Items
    /// carry no envelope of their own, may not nest another batch, and
    /// share the service's result cache within the one call. Must not
    /// be empty; the item count is capped at [`MAX_BATCH_ITEMS`].
    Batch {
        /// The sub-requests, answered in order.
        items: Vec<Request>,
    },
    /// Service counters: the result-cache hit/miss/eviction/size totals
    /// plus the engine-invocation count (cold executions of a
    /// simulator/coordinator/driver path), split per backend. Never
    /// cached.
    Stats,
    /// Enumerate the execution-backend registry with per-backend
    /// capabilities (DESIGN.md §6.8). Never cached.
    Backends,
    /// Declarative scenario (DESIGN.md §6.6): run the spec's sweep
    /// synchronously and answer every point in one envelope. The v1
    /// `sim`/`plan`/`sparsity` requests are single-point special cases
    /// of this (the service desugars them into specs internally).
    Scenario { spec: ScenarioSpec },
    /// Enqueue a scenario as an async job (DESIGN.md §6.7); answers a
    /// `job` snapshot immediately. `progress: true` asks the transport
    /// to push `progress` frames keyed by this request's `id` (only the
    /// TCP serve loop honors it, and only for top-level submits).
    Submit { spec: ScenarioSpec, progress: bool },
    /// Point-in-time job snapshot (state + completed/total points).
    JobStatus { job: u64 },
    /// The finished job's `scenario` response (`not_ready` before the
    /// terminal state, or after a cancel).
    JobResult { job: u64 },
    /// Request a cancel: queued jobs cancel immediately, running jobs
    /// between sweep points. Answers the post-action snapshot.
    JobCancel { job: u64 },
}

/// A typed response. Every variant maps 1:1 to a request type except
/// [`Response::Error`], which any request can produce.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Sim {
        makespan_ms: f64,
        speedup_vs_serial: f64,
        overlap_efficiency: f64,
        fairness: f64,
        l2_miss: f64,
        lds_util: f64,
        /// Wall-clock spent in Infinity Fabric transfers that the
        /// compute could not hide (multi-device shapes only; see
        /// `crate::fabric`). Exactly 0 on single-device points and
        /// omitted from the wire then, keeping pre-fabric responses
        /// byte-identical.
        transfer_ms: f64,
        /// Number of per-launch spans the trace replay produced
        /// (shape `trace` only; see `crate::replay`). Exactly 0 on
        /// every other shape and omitted from the wire then, keeping
        /// pre-replay responses byte-identical.
        spans: usize,
    },
    Plan {
        objective: String,
        sparse: bool,
        groups: Vec<PlanGroup>,
    },
    Sparsity {
        enable: bool,
        reason: String,
        isolated_speedup: f64,
        concurrent_speedup: f64,
    },
    Run {
        entry: String,
        outputs: usize,
        checksum: f64,
        exec_ms: f64,
    },
    Repro {
        experiment: String,
        title: String,
        report: Json,
        rendered: String,
    },
    Experiments { experiments: Vec<ExperimentInfo> },
    Config { config: Json },
    /// Per-item responses of a batch request, in item order. An item's
    /// failure is that item's `error` entry; the batch envelope itself
    /// still succeeds.
    Batch { items: Vec<Response> },
    /// Service counters (flattened on the wire as `cache_*` fields,
    /// `engine_runs`, plus one `engine_runs_<backend>` field per
    /// registered backend — `backend_runs` holds them in
    /// [`BackendId::ALL`] order). `engine_runs` stays the total cold
    /// executions (scenario points *and* repro drivers), so it can
    /// exceed the per-backend sum, which counts scenario points only.
    /// A cluster coordinator (DESIGN.md §6.9) additionally carries the
    /// `cluster_*` routing counters; standalone servers omit them, so
    /// their `stats` bytes are unchanged.
    Stats {
        cache: CacheStats,
        engine_runs: u64,
        backend_runs: Vec<u64>,
        /// `Some` only on a cluster coordinator; `None` keeps the
        /// standalone encoding byte-identical to the pre-cluster wire.
        cluster: Option<ClusterStats>,
    },
    /// The execution-backend registry (one entry per backend, registry
    /// order).
    Backends { backends: Vec<BackendInfo> },
    /// Every sweep point of a scenario, in expansion order; each item
    /// carries the point coordinates plus the envelope-less response
    /// the equivalent v1 request would produce.
    Scenario { points: Vec<PointResult> },
    /// Job snapshot (`submit`/`job_status`/`job_cancel`).
    Job(JobView),
    /// A pushed progress frame — not a response to any request, but an
    /// interleaved line keyed (via `id`) to the `submit` that asked for
    /// it. Clients must skip frames they are not waiting for.
    Progress(JobView),
    Error { code: ErrorCode, message: String },
}

/// One scheduled group inside a `plan` response.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanGroup {
    /// Kernel labels scheduled into this group.
    pub kernels: Vec<String>,
    /// ACE streams the group runs across.
    pub streams: usize,
    /// The coordinator's fairness estimate for the group.
    pub expected_fairness: f64,
    /// Whether the group demands process-level isolation.
    pub process_isolation: bool,
}

/// One registry entry inside an `experiments` response.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentInfo {
    /// Stable experiment id (`repro <id>`).
    pub id: String,
    /// Human title.
    pub title: String,
    /// Paper section the artifact reproduces.
    pub section: String,
    /// Whether the driver is a pure function of the `Config` (and its
    /// `repro` response therefore cacheable) — the registry flag from
    /// PR 3, surfaced on the wire.
    pub deterministic: bool,
}

/// One registry entry inside a `backends` response (DESIGN.md §6.8).
#[derive(Debug, Clone, PartialEq)]
pub struct BackendInfo {
    /// Stable backend id (the `"backend"` selector spelling).
    pub id: String,
    /// One-line description.
    pub description: String,
    /// Asks the backend answers (`sim`/`plan`/`sparsity` spellings).
    pub asks: Vec<String>,
    /// Stream-set shapes its `sim` ask handles.
    pub sim_shapes: Vec<String>,
    /// Whether answers are pure functions of the config (cacheable).
    pub deterministic: bool,
    /// Whether this is the serving instance's default backend.
    pub default: bool,
}

/// Coordinator-side routing counters inside a cluster `stats` response
/// (DESIGN.md §6.9). Flattened on the wire as `cluster_*` fields; the
/// block is all-or-nothing, keyed on `cluster_workers`, so a standalone
/// server's `stats` response never carries any of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClusterStats {
    /// Configured worker count (static set; includes dead workers).
    pub workers: u64,
    /// Sweep points fanned out over the hash ring (incl. retried ones
    /// counted once — retries are tracked separately).
    pub points_routed: u64,
    /// Whole non-scenario requests forwarded to their owning worker.
    pub proxied: u64,
    /// Replica fail-overs: one per attempt that moved a point or a
    /// proxied request off a dead/`overloaded` worker.
    pub retries: u64,
    /// Points (or proxied requests) that exhausted every replica and
    /// answered a typed per-point error instead.
    pub point_failures: u64,
}

/// Wire spellings of the [`ClusterStats`] block, in encode order. One
/// list shared by the encoder, the strict decoder, and the docs tests,
/// so a new counter cannot drift between them.
pub const CLUSTER_STAT_FIELDS: [&str; 5] = [
    "cluster_workers",
    "cluster_points_routed",
    "cluster_proxied",
    "cluster_retries",
    "cluster_point_failures",
];

/// Legacy text command, desugared (see [`parse_legacy`]).
#[derive(Debug, Clone, PartialEq)]
pub enum LegacyCommand {
    Quit,
    Request(Request),
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn envelope_fields(id: Option<u64>) -> Vec<(&'static str, Json)> {
    let mut fields = vec![("v", Json::Num(PROTOCOL_VERSION as f64))];
    if let Some(id) = id {
        fields.push(("id", Json::Num(id as f64)));
    }
    fields
}

impl Request {
    /// The wire `"type"` string of this variant.
    pub fn type_name(&self) -> &'static str {
        match self {
            Request::Sim { .. } => "sim",
            Request::Plan { .. } => "plan",
            Request::Sparsity { .. } => "sparsity",
            Request::Run { .. } => "run",
            Request::Repro { .. } => "repro",
            Request::ListExperiments => "list_experiments",
            Request::Config => "config",
            Request::Batch { .. } => "batch",
            Request::Stats => "stats",
            Request::Backends => "backends",
            Request::Scenario { .. } => "scenario",
            Request::Submit { .. } => "submit",
            Request::JobStatus { .. } => "job_status",
            Request::JobResult { .. } => "job_result",
            Request::JobCancel { .. } => "job_cancel",
        }
    }

    /// Encode as one wire object (the caller newline-frames it).
    pub fn to_json(&self, id: Option<u64>) -> Json {
        self.to_json_opts(id, true)
    }

    /// Encode with explicit envelope options. `cache: true` (the
    /// default) is omitted on the wire, so it round-trips to the same
    /// bytes as [`Request::to_json`]; `cache: false` emits the
    /// `"cache":false` escape hatch.
    pub fn to_json_opts(&self, id: Option<u64>, cache: bool) -> Json {
        self.to_json_env(&RequestEnvelope { id, cache, backend: None })
    }

    /// Encode with a full [`RequestEnvelope`]. Defaults (`cache: true`,
    /// `backend: None`) are omitted on the wire, so the canonical form
    /// of a default-envelope request is byte-identical to
    /// [`Request::to_json`].
    ///
    /// Caveat: a top-level `scenario` request flattens its spec into
    /// the payload, so the spec-level `backend` field and the envelope
    /// key are literally the same wire key — a spec that names a
    /// backend wins (keys are a map and the payload is pushed last),
    /// and a *disagreeing* envelope selector is unrepresentable.
    /// [`super::Client::request_json_env`] refuses to encode that pair;
    /// the server rejects it whenever both are visible (`submit` nests
    /// its spec, so both survive there).
    pub fn to_json_env(&self, env: &RequestEnvelope) -> Json {
        let mut fields = envelope_fields(env.id);
        if let Some(b) = env.backend {
            fields.push(("backend", Json::Str(b.as_str().into())));
        }
        if !env.cache {
            fields.push(("cache", Json::Bool(false)));
        }
        fields.push(("type", Json::Str(self.type_name().into())));
        self.push_payload(&mut fields);
        Json::obj(fields)
    }

    /// Encode `type` + payload only (no envelope keys) — the form batch
    /// items take on the wire.
    pub fn to_item_json(&self) -> Json {
        let mut fields = vec![("type", Json::Str(self.type_name().into()))];
        self.push_payload(&mut fields);
        Json::obj(fields)
    }

    /// The canonical cache key: the envelope-less wire form. Object
    /// keys serialize sorted and precision/objective spellings are
    /// normalized into enums at decode time, so semantically identical
    /// requests collide on one key no matter how they were spelled or
    /// which transport carried them.
    pub fn cache_key(&self) -> String {
        self.to_item_json().to_string()
    }

    fn push_payload(&self, fields: &mut Vec<(&'static str, Json)>) {
        match self {
            Request::Sim { n, precision, streams } => {
                fields.push(("n", Json::Num(*n as f64)));
                fields.push((
                    "precision",
                    Json::Str(precision_wire_name(*precision).into()),
                ));
                fields.push(("streams", Json::Num(*streams as f64)));
            }
            Request::Plan { objective, streams, n, precision } => {
                fields.push((
                    "objective",
                    Json::Str(objective_name(*objective).into()),
                ));
                fields.push(("streams", Json::Num(*streams as f64)));
                fields.push(("n", Json::Num(*n as f64)));
                fields.push((
                    "precision",
                    Json::Str(precision_wire_name(*precision).into()),
                ));
            }
            Request::Sparsity { n, streams } => {
                fields.push(("n", Json::Num(*n as f64)));
                fields.push(("streams", Json::Num(*streams as f64)));
            }
            Request::Run { entry } => {
                fields.push(("entry", Json::Str(entry.clone())));
            }
            Request::Repro { experiment } => {
                fields.push(("experiment", Json::Str(experiment.clone())));
            }
            Request::Batch { items } => {
                fields.push((
                    "items",
                    Json::Arr(
                        items.iter().map(|r| r.to_item_json()).collect(),
                    ),
                ));
            }
            Request::Scenario { spec } => spec.push_payload(fields),
            Request::Submit { spec, progress } => {
                // `progress: false` is the default and omitted, keeping
                // the canonical form minimal.
                if *progress {
                    fields.push(("progress", Json::Bool(true)));
                }
                fields.push(("spec", spec.to_json()));
            }
            Request::JobStatus { job }
            | Request::JobResult { job }
            | Request::JobCancel { job } => {
                fields.push(("job", Json::Num(*job as f64)));
            }
            Request::ListExperiments
            | Request::Config
            | Request::Stats
            | Request::Backends => {}
        }
    }

    /// Decode a wire object. On failure the envelope `id` is still
    /// salvaged when possible, so transports can address the error reply.
    pub fn from_json(
        v: &Json,
    ) -> Result<(Request, Option<u64>), (ApiError, Option<u64>)> {
        Request::decode(v).map(|(req, env)| (req, env.id))
    }

    /// Full decode: the request plus its [`RequestEnvelope`] options
    /// (`id`, `cache`). Transports that honor the cache escape hatch
    /// use this; [`Request::from_json`] is the id-only convenience.
    pub fn decode(
        v: &Json,
    ) -> Result<(Request, RequestEnvelope), (ApiError, Option<u64>)> {
        let salvaged = salvage_id(v);
        let (m, id, ty, cache, backend) =
            envelope(v, "request").map_err(|e| (e, salvaged))?;
        decode_request_payload(m, ty)
            .map(|r| {
                (
                    r,
                    RequestEnvelope {
                        id,
                        cache: cache.unwrap_or(true),
                        backend,
                    },
                )
            })
            .map_err(|e| (e, id))
    }
}

fn decode_request_payload(
    m: &BTreeMap<String, Json>,
    ty: &str,
) -> Result<Request, ApiError> {
    match ty {
        "sim" => {
            check_env_fields(m, ty, &["n", "precision", "streams"])?;
            Ok(Request::Sim {
                n: usize_field(m, ty, "n")?,
                precision: precision_field(m, ty)?,
                streams: usize_field(m, ty, "streams")?,
            })
        }
        "plan" => {
            check_env_fields(
                m,
                ty,
                &["objective", "streams", "n", "precision"],
            )?;
            let o = str_field(m, ty, "objective")?;
            Ok(Request::Plan {
                objective: parse_objective(o).ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "{ty}: bad objective {o:?} (want \
                         latency|throughput|isolation)"
                    ))
                })?,
                streams: usize_field(m, ty, "streams")?,
                n: usize_field(m, ty, "n")?,
                precision: precision_field(m, ty)?,
            })
        }
        "sparsity" => {
            check_env_fields(m, ty, &["n", "streams"])?;
            Ok(Request::Sparsity {
                n: usize_field(m, ty, "n")?,
                streams: usize_field(m, ty, "streams")?,
            })
        }
        "run" => {
            check_env_fields(m, ty, &["entry"])?;
            Ok(Request::Run { entry: str_field(m, ty, "entry")?.to_string() })
        }
        "repro" => {
            check_env_fields(m, ty, &["experiment"])?;
            Ok(Request::Repro {
                experiment: str_field(m, ty, "experiment")?.to_string(),
            })
        }
        "list_experiments" => {
            check_env_fields(m, ty, &[])?;
            Ok(Request::ListExperiments)
        }
        "config" => {
            check_env_fields(m, ty, &[])?;
            Ok(Request::Config)
        }
        "batch" => {
            check_env_fields(m, ty, &["items"])?;
            let raw = arr_field(m, ty, "items")?;
            if raw.is_empty() {
                return Err(ApiError::bad_request(
                    "batch: \"items\" must not be empty",
                ));
            }
            // Cap before the per-item decode loop, so an absurd batch
            // is rejected without building a Request per item.
            if raw.len() > MAX_BATCH_ITEMS {
                return Err(ApiError::new(
                    ErrorCode::BadRange,
                    format!(
                        "batch items must be in 1..={MAX_BATCH_ITEMS} \
                         (got {})",
                        raw.len()
                    ),
                ));
            }
            let items = raw
                .iter()
                .enumerate()
                .map(|(i, item)| decode_batch_item(item, i))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Request::Batch { items })
        }
        "stats" => {
            check_env_fields(m, ty, &[])?;
            Ok(Request::Stats)
        }
        "backends" => {
            check_env_fields(m, ty, &[])?;
            Ok(Request::Backends)
        }
        "scenario" => {
            check_env_fields(m, ty, scenario::SPEC_FIELDS)?;
            Ok(Request::Scenario {
                spec: ScenarioSpec::decode_fields(m, ty)?,
            })
        }
        "submit" => {
            check_env_fields(m, ty, &["progress", "spec"])?;
            let sv = any_field(m, ty, "spec")?;
            let sm = obj(sv, "submit spec")?;
            check_obj_fields(sm, "submit spec", scenario::SPEC_FIELDS)?;
            let spec = ScenarioSpec::decode_fields(sm, "submit spec")?;
            let progress = match m.get("progress") {
                None => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => {
                    return Err(ApiError::bad_request(
                        "submit: field \"progress\" must be a boolean",
                    ))
                }
            };
            Ok(Request::Submit { spec, progress })
        }
        "job_status" => {
            check_env_fields(m, ty, &["job"])?;
            Ok(Request::JobStatus { job: u64_field(m, ty, "job")? })
        }
        "job_result" => {
            check_env_fields(m, ty, &["job"])?;
            Ok(Request::JobResult { job: u64_field(m, ty, "job")? })
        }
        "job_cancel" => {
            check_env_fields(m, ty, &["job"])?;
            Ok(Request::JobCancel { job: u64_field(m, ty, "job")? })
        }
        other => Err(ApiError::new(
            ErrorCode::UnknownType,
            format!("unknown request type {other:?}"),
        )),
    }
}

/// Shared envelope rules for one batch item, request or response side:
/// it must be an object, envelope keys (`v`/`id`/`cache`, and
/// `backend` except on `scenario` items — where it is a legitimate
/// ScenarioSpec payload field, exactly as on a top-level scenario
/// line) belong to the batch line rather than to items, and batches do
/// not nest. Returns the item's map and `type` so the caller runs the
/// payload decoder.
fn item_envelope<'a>(
    v: &'a Json,
    what: &str,
) -> Result<(&'a BTreeMap<String, Json>, &'a str), ApiError> {
    let m = obj(v, what)?;
    let ty = match m.get("type") {
        Some(Json::Str(s)) => s.as_str(),
        Some(_) => {
            return Err(ApiError::bad_request(format!(
                "{what}: field \"type\" must be a string"
            )))
        }
        None => {
            return Err(ApiError::bad_request(format!(
                "{what}: missing field \"type\""
            )))
        }
    };
    if ty == "batch" {
        return Err(ApiError::bad_request(format!(
            "{what}: batches do not nest"
        )));
    }
    for k in ["v", "id", "cache", "backend"] {
        if k == "backend" && ty == "scenario" {
            continue; // a spec field there, decoded by ScenarioSpec
        }
        if m.contains_key(k) {
            return Err(ApiError::bad_request(format!(
                "{what}: {k:?} belongs on the batch envelope, not on items"
            )));
        }
    }
    Ok((m, ty))
}

/// Decode one batch item: an envelope-less request object
/// ([`item_envelope`] rules), so every item decodes exactly like a
/// standalone request payload.
fn decode_batch_item(v: &Json, idx: usize) -> Result<Request, ApiError> {
    let what = format!("batch item {idx}");
    let (m, ty) = item_envelope(v, &what)?;
    decode_request_payload(m, ty)
        .map_err(|e| ApiError::new(e.code, format!("{what}: {}", e.message)))
}

impl Response {
    /// The wire `"type"` string of this variant.
    pub fn type_name(&self) -> &'static str {
        match self {
            Response::Sim { .. } => "sim",
            Response::Plan { .. } => "plan",
            Response::Sparsity { .. } => "sparsity",
            Response::Run { .. } => "run",
            Response::Repro { .. } => "repro",
            Response::Experiments { .. } => "experiments",
            Response::Config { .. } => "config",
            Response::Batch { .. } => "batch",
            Response::Stats { .. } => "stats",
            Response::Backends { .. } => "backends",
            Response::Scenario { .. } => "scenario",
            Response::Job(_) => "job",
            Response::Progress(_) => "progress",
            Response::Error { .. } => "error",
        }
    }

    /// Encode as one wire object, echoing the request's `id`.
    pub fn to_json(&self, id: Option<u64>) -> Json {
        let mut fields = envelope_fields(id);
        fields.push(("type", Json::Str(self.type_name().into())));
        self.push_payload(&mut fields);
        Json::obj(fields)
    }

    /// Encode `type` + payload only — the form batch response items
    /// take on the wire.
    pub fn to_item_json(&self) -> Json {
        let mut fields = vec![("type", Json::Str(self.type_name().into()))];
        self.push_payload(&mut fields);
        Json::obj(fields)
    }

    fn push_payload(&self, fields: &mut Vec<(&'static str, Json)>) {
        match self {
            Response::Sim {
                makespan_ms,
                speedup_vs_serial,
                overlap_efficiency,
                fairness,
                l2_miss,
                lds_util,
                transfer_ms,
                spans,
            } => {
                fields.push(("makespan_ms", Json::Num(*makespan_ms)));
                fields.push((
                    "speedup_vs_serial",
                    Json::Num(*speedup_vs_serial),
                ));
                fields.push((
                    "overlap_efficiency",
                    Json::Num(*overlap_efficiency),
                ));
                fields.push(("fairness", Json::Num(*fairness)));
                fields.push(("l2_miss", Json::Num(*l2_miss)));
                fields.push(("lds_util", Json::Num(*lds_util)));
                if *transfer_ms > 0.0 {
                    fields.push(("transfer_ms", Json::Num(*transfer_ms)));
                }
                if *spans > 0 {
                    fields.push(("spans", Json::Num(*spans as f64)));
                }
            }
            Response::Plan { objective, sparse, groups } => {
                fields.push(("objective", Json::Str(objective.clone())));
                fields.push(("sparse", Json::Bool(*sparse)));
                fields.push((
                    "groups",
                    Json::Arr(
                        groups
                            .iter()
                            .map(|g| {
                                Json::obj(vec![
                                    (
                                        "kernels",
                                        Json::Arr(
                                            g.kernels
                                                .iter()
                                                .map(|k| {
                                                    Json::Str(k.clone())
                                                })
                                                .collect(),
                                        ),
                                    ),
                                    (
                                        "streams",
                                        Json::Num(g.streams as f64),
                                    ),
                                    (
                                        "expected_fairness",
                                        Json::Num(g.expected_fairness),
                                    ),
                                    (
                                        "process_isolation",
                                        Json::Bool(g.process_isolation),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            Response::Sparsity {
                enable,
                reason,
                isolated_speedup,
                concurrent_speedup,
            } => {
                fields.push(("enable", Json::Bool(*enable)));
                fields.push(("reason", Json::Str(reason.clone())));
                fields.push((
                    "isolated_speedup",
                    Json::Num(*isolated_speedup),
                ));
                fields.push((
                    "concurrent_speedup",
                    Json::Num(*concurrent_speedup),
                ));
            }
            Response::Run { entry, outputs, checksum, exec_ms } => {
                fields.push(("entry", Json::Str(entry.clone())));
                fields.push(("outputs", Json::Num(*outputs as f64)));
                fields.push(("checksum", Json::Num(*checksum)));
                fields.push(("exec_ms", Json::Num(*exec_ms)));
            }
            Response::Repro { experiment, title, report, rendered } => {
                fields.push(("experiment", Json::Str(experiment.clone())));
                fields.push(("title", Json::Str(title.clone())));
                fields.push(("report", report.clone()));
                fields.push(("rendered", Json::Str(rendered.clone())));
            }
            Response::Experiments { experiments } => {
                fields.push((
                    "experiments",
                    Json::Arr(
                        experiments
                            .iter()
                            .map(|e| {
                                Json::obj(vec![
                                    (
                                        "deterministic",
                                        Json::Bool(e.deterministic),
                                    ),
                                    ("id", Json::Str(e.id.clone())),
                                    ("title", Json::Str(e.title.clone())),
                                    (
                                        "section",
                                        Json::Str(e.section.clone()),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            Response::Config { config } => {
                fields.push(("config", config.clone()));
            }
            Response::Batch { items } => {
                fields.push((
                    "items",
                    Json::Arr(
                        items.iter().map(|r| r.to_item_json()).collect(),
                    ),
                ));
            }
            Response::Backends { backends } => {
                fields.push((
                    "backends",
                    Json::Arr(
                        backends
                            .iter()
                            .map(|b| {
                                Json::obj(vec![
                                    (
                                        "asks",
                                        str_arr_json(&b.asks),
                                    ),
                                    ("default", Json::Bool(b.default)),
                                    (
                                        "deterministic",
                                        Json::Bool(b.deterministic),
                                    ),
                                    (
                                        "description",
                                        Json::Str(b.description.clone()),
                                    ),
                                    ("id", Json::Str(b.id.clone())),
                                    (
                                        "sim_shapes",
                                        str_arr_json(&b.sim_shapes),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            Response::Stats { cache, engine_runs, backend_runs, cluster } => {
                fields.push(("cache_hits", Json::Num(cache.hits as f64)));
                fields
                    .push(("cache_misses", Json::Num(cache.misses as f64)));
                fields.push((
                    "cache_evictions",
                    Json::Num(cache.evictions as f64),
                ));
                fields
                    .push(("cache_entries", Json::Num(cache.entries as f64)));
                fields.push(("cache_bytes", Json::Num(cache.bytes as f64)));
                fields.push((
                    "cache_max_entries",
                    Json::Num(cache.max_entries as f64),
                ));
                fields.push((
                    "cache_max_bytes",
                    Json::Num(cache.max_bytes as f64),
                ));
                fields.push(("cache_enabled", Json::Bool(cache.enabled)));
                fields.push(("engine_runs", Json::Num(*engine_runs as f64)));
                // One counter field per registered backend, named after
                // its id (keys serialize sorted; missing trailing
                // entries encode as 0 for programmatic constructions).
                for (i, id) in BackendId::ALL.iter().enumerate() {
                    fields.push((
                        id.stat_field(),
                        Json::Num(
                            backend_runs.get(i).copied().unwrap_or(0)
                                as f64,
                        ),
                    ));
                }
                if let Some(c) = cluster {
                    fields.push((
                        "cluster_workers",
                        Json::Num(c.workers as f64),
                    ));
                    fields.push((
                        "cluster_points_routed",
                        Json::Num(c.points_routed as f64),
                    ));
                    fields.push((
                        "cluster_proxied",
                        Json::Num(c.proxied as f64),
                    ));
                    fields.push((
                        "cluster_retries",
                        Json::Num(c.retries as f64),
                    ));
                    fields.push((
                        "cluster_point_failures",
                        Json::Num(c.point_failures as f64),
                    ));
                }
            }
            Response::Scenario { points } => {
                fields.push((
                    "points",
                    Json::Arr(
                        points
                            .iter()
                            .map(|pr| {
                                Json::obj(vec![
                                    ("point", pr.point.to_json()),
                                    ("result", pr.result.to_item_json()),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            Response::Job(v) | Response::Progress(v) => {
                fields.push(("completed", Json::Num(v.completed as f64)));
                fields.push(("job", Json::Num(v.job as f64)));
                // Only budgeted auto jobs ever refine; omitting the
                // zero keeps every pre-refinement frame byte-identical.
                if v.refined > 0 {
                    fields.push(("refined", Json::Num(v.refined as f64)));
                }
                fields.push(("state", Json::Str(v.state.as_str().into())));
                fields.push(("total", Json::Num(v.total as f64)));
            }
            Response::Error { code, message } => {
                fields.push(("code", Json::Str(code.as_str().into())));
                fields.push(("error", Json::Str(message.clone())));
            }
        }
    }

    /// Decode a wire object (client side). Strict: unknown fields and
    /// foreign versions are rejected, mirroring request decoding.
    pub fn from_json(v: &Json) -> Result<(Response, Option<u64>), ApiError> {
        let (m, id, ty, cache, backend) = envelope(v, "response")?;
        if cache.is_some() {
            return Err(ApiError::bad_request(
                "\"cache\" is a request-envelope key; responses never \
                 carry it",
            ));
        }
        if backend.is_some() {
            return Err(ApiError::bad_request(
                "\"backend\" is a request-envelope key; responses never \
                 carry it",
            ));
        }
        let resp = decode_response_payload(m, ty)?;
        Ok((resp, id))
    }
}

impl From<ApiError> for Response {
    fn from(e: ApiError) -> Response {
        Response::Error { code: e.code, message: e.message }
    }
}

fn decode_response_payload(
    m: &BTreeMap<String, Json>,
    ty: &str,
) -> Result<Response, ApiError> {
    match ty {
        "sim" => {
            check_env_fields(
                m,
                ty,
                &[
                    "makespan_ms",
                    "speedup_vs_serial",
                    "overlap_efficiency",
                    "fairness",
                    "l2_miss",
                    "lds_util",
                    "transfer_ms",
                    "spans",
                ],
            )?;
            Ok(Response::Sim {
                makespan_ms: f64_field(m, ty, "makespan_ms")?,
                speedup_vs_serial: f64_field(m, ty, "speedup_vs_serial")?,
                overlap_efficiency: f64_field(m, ty, "overlap_efficiency")?,
                fairness: f64_field(m, ty, "fairness")?,
                l2_miss: f64_field(m, ty, "l2_miss")?,
                lds_util: f64_field(m, ty, "lds_util")?,
                transfer_ms: if m.contains_key("transfer_ms") {
                    f64_field(m, ty, "transfer_ms")?
                } else {
                    0.0
                },
                spans: if m.contains_key("spans") {
                    usize_field(m, ty, "spans")?
                } else {
                    0
                },
            })
        }
        "plan" => {
            check_env_fields(m, ty, &["objective", "sparse", "groups"])?;
            let groups = arr_field(m, ty, "groups")?
                .iter()
                .map(|g| decode_plan_group(g))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::Plan {
                objective: str_field(m, ty, "objective")?.to_string(),
                sparse: bool_field(m, ty, "sparse")?,
                groups,
            })
        }
        "sparsity" => {
            check_env_fields(
                m,
                ty,
                &["enable", "reason", "isolated_speedup",
                  "concurrent_speedup"],
            )?;
            Ok(Response::Sparsity {
                enable: bool_field(m, ty, "enable")?,
                reason: str_field(m, ty, "reason")?.to_string(),
                isolated_speedup: f64_field(m, ty, "isolated_speedup")?,
                concurrent_speedup: f64_field(m, ty, "concurrent_speedup")?,
            })
        }
        "run" => {
            check_env_fields(
                m,
                ty,
                &["entry", "outputs", "checksum", "exec_ms"],
            )?;
            Ok(Response::Run {
                entry: str_field(m, ty, "entry")?.to_string(),
                outputs: usize_field(m, ty, "outputs")?,
                checksum: f64_field(m, ty, "checksum")?,
                exec_ms: f64_field(m, ty, "exec_ms")?,
            })
        }
        "repro" => {
            check_env_fields(
                m,
                ty,
                &["experiment", "title", "report", "rendered"],
            )?;
            Ok(Response::Repro {
                experiment: str_field(m, ty, "experiment")?.to_string(),
                title: str_field(m, ty, "title")?.to_string(),
                report: any_field(m, ty, "report")?.clone(),
                rendered: str_field(m, ty, "rendered")?.to_string(),
            })
        }
        "experiments" => {
            check_env_fields(m, ty, &["experiments"])?;
            let experiments = arr_field(m, ty, "experiments")?
                .iter()
                .map(|e| decode_experiment_info(e))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::Experiments { experiments })
        }
        "config" => {
            check_env_fields(m, ty, &["config"])?;
            Ok(Response::Config { config: any_field(m, ty, "config")?.clone() })
        }
        "batch" => {
            check_env_fields(m, ty, &["items"])?;
            let items = arr_field(m, ty, "items")?
                .iter()
                .enumerate()
                .map(|(i, item)| decode_response_item(item, i))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::Batch { items })
        }
        "stats" => {
            // The per-backend counter fields are derived from the
            // registry, so adding a backend cannot leave this strict
            // decoder stale.
            let mut allowed: Vec<&str> = vec![
                "cache_hits",
                "cache_misses",
                "cache_evictions",
                "cache_entries",
                "cache_bytes",
                "cache_max_entries",
                "cache_max_bytes",
                "cache_enabled",
                "engine_runs",
            ];
            allowed.extend(BackendId::ALL.iter().map(|b| b.stat_field()));
            allowed.extend(CLUSTER_STAT_FIELDS);
            check_env_fields(m, ty, &allowed)?;
            let backend_runs = BackendId::ALL
                .iter()
                .map(|b| u64_field(m, ty, b.stat_field()))
                .collect::<Result<Vec<_>, _>>()?;
            // The cluster block is all-or-nothing, keyed on
            // `cluster_workers`: present means every `cluster_*` field
            // is required, absent means none may appear.
            let cluster = if m.contains_key("cluster_workers") {
                Some(ClusterStats {
                    workers: u64_field(m, ty, "cluster_workers")?,
                    points_routed: u64_field(
                        m,
                        ty,
                        "cluster_points_routed",
                    )?,
                    proxied: u64_field(m, ty, "cluster_proxied")?,
                    retries: u64_field(m, ty, "cluster_retries")?,
                    point_failures: u64_field(
                        m,
                        ty,
                        "cluster_point_failures",
                    )?,
                })
            } else {
                for k in CLUSTER_STAT_FIELDS {
                    if m.contains_key(k) {
                        return Err(ApiError::bad_request(format!(
                            "stats: {k:?} requires the full cluster_* \
                             block (missing \"cluster_workers\")"
                        )));
                    }
                }
                None
            };
            Ok(Response::Stats {
                cache: CacheStats {
                    hits: u64_field(m, ty, "cache_hits")?,
                    misses: u64_field(m, ty, "cache_misses")?,
                    evictions: u64_field(m, ty, "cache_evictions")?,
                    entries: u64_field(m, ty, "cache_entries")?,
                    bytes: u64_field(m, ty, "cache_bytes")?,
                    max_entries: u64_field(m, ty, "cache_max_entries")?,
                    max_bytes: u64_field(m, ty, "cache_max_bytes")?,
                    enabled: bool_field(m, ty, "cache_enabled")?,
                },
                engine_runs: u64_field(m, ty, "engine_runs")?,
                backend_runs,
                cluster,
            })
        }
        "backends" => {
            check_env_fields(m, ty, &["backends"])?;
            let backends = arr_field(m, ty, "backends")?
                .iter()
                .map(decode_backend_info)
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::Backends { backends })
        }
        "scenario" => {
            check_env_fields(m, ty, &["points"])?;
            let points = arr_field(m, ty, "points")?
                .iter()
                .enumerate()
                .map(|(i, v)| decode_point_result(v, i))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::Scenario { points })
        }
        "job" => Ok(Response::Job(decode_job_view(m, ty)?)),
        "progress" => Ok(Response::Progress(decode_job_view(m, ty)?)),
        "error" => {
            check_env_fields(m, ty, &["code", "error"])?;
            let code = str_field(m, ty, "code")?;
            Ok(Response::Error {
                code: ErrorCode::parse(code).ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "error: unknown error code {code:?}"
                    ))
                })?,
                message: str_field(m, ty, "error")?.to_string(),
            })
        }
        other => Err(ApiError::new(
            ErrorCode::UnknownType,
            format!("unknown response type {other:?}"),
        )),
    }
}

/// Decode one `{"point":…,"result":…}` scenario item. The result is an
/// envelope-less response object under [`item_envelope`] rules, exactly
/// like a batch item.
fn decode_point_result(v: &Json, idx: usize) -> Result<PointResult, ApiError> {
    let what = format!("scenario point {idx}");
    let m = obj(v, &what)?;
    check_obj_fields(m, &what, &["point", "result"])?;
    let point = Point::from_json(any_field(m, &what, "point")?, &what)?;
    let (rm, rty) = item_envelope(any_field(m, &what, "result")?, &what)?;
    let result = decode_response_payload(rm, rty).map_err(|e| {
        ApiError::new(e.code, format!("{what}: {}", e.message))
    })?;
    Ok(PointResult { point, result: Box::new(result) })
}

/// Decode the shared `job`/`progress` payload.
fn decode_job_view(
    m: &BTreeMap<String, Json>,
    ty: &str,
) -> Result<JobView, ApiError> {
    check_env_fields(
        m,
        ty,
        &["completed", "job", "refined", "state", "total"],
    )?;
    let s = str_field(m, ty, "state")?;
    Ok(JobView {
        job: u64_field(m, ty, "job")?,
        state: JobState::parse(s).ok_or_else(|| {
            ApiError::bad_request(format!("{ty}: unknown job state {s:?}"))
        })?,
        completed: u64_field(m, ty, "completed")?,
        refined: if m.contains_key("refined") {
            u64_field(m, ty, "refined")?
        } else {
            0
        },
        total: u64_field(m, ty, "total")?,
    })
}

/// Decode one batch response item ([`item_envelope`] rules, response
/// payload decoder).
fn decode_response_item(v: &Json, idx: usize) -> Result<Response, ApiError> {
    let what = format!("batch response item {idx}");
    let (m, ty) = item_envelope(v, &what)?;
    decode_response_payload(m, ty)
        .map_err(|e| ApiError::new(e.code, format!("{what}: {}", e.message)))
}

fn decode_plan_group(v: &Json) -> Result<PlanGroup, ApiError> {
    let m = obj(v, "plan group")?;
    check_obj_fields(
        m,
        "plan group",
        &["kernels", "streams", "expected_fairness", "process_isolation"],
    )?;
    let kernels = arr_field(m, "plan group", "kernels")?
        .iter()
        .map(|k| {
            k.as_str().map(str::to_string).ok_or_else(|| {
                ApiError::bad_request("plan group: kernels must be strings")
            })
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(PlanGroup {
        kernels,
        streams: usize_field(m, "plan group", "streams")?,
        expected_fairness: f64_field(m, "plan group", "expected_fairness")?,
        process_isolation: bool_field(m, "plan group", "process_isolation")?,
    })
}

fn decode_experiment_info(v: &Json) -> Result<ExperimentInfo, ApiError> {
    let m = obj(v, "experiment entry")?;
    check_obj_fields(
        m,
        "experiment entry",
        &["deterministic", "id", "title", "section"],
    )?;
    Ok(ExperimentInfo {
        id: str_field(m, "experiment entry", "id")?.to_string(),
        title: str_field(m, "experiment entry", "title")?.to_string(),
        section: str_field(m, "experiment entry", "section")?.to_string(),
        deterministic: bool_field(m, "experiment entry", "deterministic")?,
    })
}

/// Encode a string list as a JSON array.
fn str_arr_json(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

/// Decode a JSON array of strings.
fn str_arr_field(
    m: &BTreeMap<String, Json>,
    what: &str,
    key: &str,
) -> Result<Vec<String>, ApiError> {
    arr_field(m, what, key)?
        .iter()
        .map(|x| {
            x.as_str().map(str::to_string).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "{what}: field {key:?} must be an array of strings"
                ))
            })
        })
        .collect()
}

fn decode_backend_info(v: &Json) -> Result<BackendInfo, ApiError> {
    let what = "backend entry";
    let m = obj(v, what)?;
    check_obj_fields(
        m,
        what,
        &["asks", "default", "deterministic", "description", "id",
          "sim_shapes"],
    )?;
    Ok(BackendInfo {
        id: str_field(m, what, "id")?.to_string(),
        description: str_field(m, what, "description")?.to_string(),
        asks: str_arr_field(m, what, "asks")?,
        sim_shapes: str_arr_field(m, what, "sim_shapes")?,
        deterministic: bool_field(m, what, "deterministic")?,
        default: bool_field(m, what, "default")?,
    })
}

// ---------------------------------------------------------------------
// Envelope / field helpers
// ---------------------------------------------------------------------

pub(crate) fn obj<'a>(
    v: &'a Json,
    what: &str,
) -> Result<&'a BTreeMap<String, Json>, ApiError> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => Err(ApiError::bad_request(format!(
            "{what} must be a JSON object"
        ))),
    }
}

type EnvelopeParts<'a> = (
    &'a BTreeMap<String, Json>,
    Option<u64>,
    &'a str,
    Option<bool>,
    Option<BackendId>,
);

fn envelope<'a>(
    v: &'a Json,
    what: &str,
) -> Result<EnvelopeParts<'a>, ApiError> {
    let m = obj(v, what)?;
    match m.get("v") {
        Some(Json::Num(x)) if *x == PROTOCOL_VERSION as f64 => {}
        Some(Json::Num(x)) => {
            return Err(ApiError::new(
                ErrorCode::BadVersion,
                format!(
                    "unsupported protocol version {x} (this build speaks \
                     v{PROTOCOL_VERSION})"
                ),
            ))
        }
        Some(_) => {
            return Err(ApiError::new(
                ErrorCode::BadVersion,
                "field \"v\" must be a number",
            ))
        }
        None => {
            return Err(ApiError::new(
                ErrorCode::BadVersion,
                format!(
                    "missing protocol version field \"v\" (expected \
                     {PROTOCOL_VERSION})"
                ),
            ))
        }
    }
    let id = match m.get("id") {
        None => None,
        Some(Json::Num(x))
            if x.fract() == 0.0 && *x >= 0.0 && *x <= 9.0e15 =>
        {
            Some(*x as u64)
        }
        Some(_) => {
            return Err(ApiError::bad_request(
                "field \"id\" must be a nonnegative integer",
            ))
        }
    };
    let ty = match m.get("type") {
        Some(Json::Str(s)) => s.as_str(),
        Some(_) => {
            return Err(ApiError::bad_request(
                "field \"type\" must be a string",
            ))
        }
        None => {
            return Err(ApiError::bad_request(format!(
                "{what}: missing field \"type\""
            )))
        }
    };
    let cache = match m.get("cache") {
        None => None,
        Some(Json::Bool(b)) => Some(*b),
        Some(_) => {
            return Err(ApiError::bad_request(
                "field \"cache\" must be a boolean",
            ))
        }
    };
    let backend = match m.get("backend") {
        None => None,
        Some(Json::Str(s)) => {
            Some(BackendId::parse(s).ok_or_else(|| {
                ApiError::new(
                    ErrorCode::UnknownBackend,
                    format!(
                        "unknown backend {s:?} (registered: {})",
                        BackendId::names()
                    ),
                )
            })?)
        }
        Some(_) => {
            return Err(ApiError::bad_request(
                "field \"backend\" must be a string",
            ))
        }
    };
    Ok((m, id, ty, cache, backend))
}

fn salvage_id(v: &Json) -> Option<u64> {
    match v.get("id") {
        Some(Json::Num(x))
            if x.fract() == 0.0 && *x >= 0.0 && *x <= 9.0e15 =>
        {
            Some(*x as u64)
        }
        _ => None,
    }
}

/// Reject payload keys outside `allowed` (envelope keys exempt).
fn check_env_fields(
    m: &BTreeMap<String, Json>,
    ty: &str,
    allowed: &[&str],
) -> Result<(), ApiError> {
    for k in m.keys() {
        let k = k.as_str();
        if k != "v"
            && k != "id"
            && k != "type"
            && k != "cache"
            && k != "backend"
            && !allowed.contains(&k)
        {
            return Err(ApiError::new(
                ErrorCode::UnknownField,
                format!("{ty}: unknown field {k:?}"),
            ));
        }
    }
    Ok(())
}

/// Reject keys outside `allowed` in a nested (non-envelope) object.
pub(crate) fn check_obj_fields(
    m: &BTreeMap<String, Json>,
    what: &str,
    allowed: &[&str],
) -> Result<(), ApiError> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(ApiError::new(
                ErrorCode::UnknownField,
                format!("{what}: unknown field {k:?}"),
            ));
        }
    }
    Ok(())
}

fn any_field<'a>(
    m: &'a BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<&'a Json, ApiError> {
    m.get(key).ok_or_else(|| {
        ApiError::bad_request(format!("{ty}: missing field {key:?}"))
    })
}

fn f64_field(
    m: &BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<f64, ApiError> {
    match any_field(m, ty, key)? {
        Json::Num(x) => Ok(*x),
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be a number"
        ))),
    }
}

fn u64_field(
    m: &BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<u64, ApiError> {
    match any_field(m, ty, key)? {
        Json::Num(x)
            if x.fract() == 0.0 && *x >= 0.0 && *x <= 9.0e15 =>
        {
            Ok(*x as u64)
        }
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be a nonnegative integer"
        ))),
    }
}

pub(crate) fn usize_field(
    m: &BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<usize, ApiError> {
    match any_field(m, ty, key)? {
        Json::Num(x)
            if x.fract() == 0.0 && *x >= 0.0 && *x <= 9.0e15 =>
        {
            Ok(*x as usize)
        }
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be a nonnegative integer"
        ))),
    }
}

fn bool_field(
    m: &BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<bool, ApiError> {
    match any_field(m, ty, key)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be a boolean"
        ))),
    }
}

pub(crate) fn str_field<'a>(
    m: &'a BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<&'a str, ApiError> {
    match any_field(m, ty, key)? {
        Json::Str(s) => Ok(s.as_str()),
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be a string"
        ))),
    }
}

fn arr_field<'a>(
    m: &'a BTreeMap<String, Json>,
    ty: &str,
    key: &str,
) -> Result<&'a [Json], ApiError> {
    match any_field(m, ty, key)? {
        Json::Arr(a) => Ok(a.as_slice()),
        _ => Err(ApiError::bad_request(format!(
            "{ty}: field {key:?} must be an array"
        ))),
    }
}

fn precision_field(
    m: &BTreeMap<String, Json>,
    ty: &str,
) -> Result<Precision, ApiError> {
    let s = str_field(m, ty, "precision")?;
    Precision::parse(s).ok_or_else(|| {
        ApiError::bad_request(format!("{ty}: bad precision {s:?}"))
    })
}

// ---------------------------------------------------------------------
// Legacy text shim
// ---------------------------------------------------------------------

/// Desugar one legacy text line (`SIM 512 fp8 4`, ...) into a typed
/// request. The shim preserves the PR-1 *command* framing only; the
/// response is the v1 envelope (so e.g. a `PLAN` reply now carries
/// structured `groups` objects plus `v`/`type` keys, not the pre-API
/// flat arrays). The serve loop answers a desugared request
/// byte-identically to its JSON form (without an `id`).
pub fn parse_legacy(line: &str) -> Result<LegacyCommand, ApiError> {
    let parts: Vec<&str> = line.split_whitespace().collect();
    let req = match parts.as_slice() {
        ["QUIT"] | ["quit"] => return Ok(LegacyCommand::Quit),
        ["SIM", n, prec, streams] => Request::Sim {
            n: parse_count(n, "size")?,
            precision: Precision::parse(prec).ok_or_else(|| {
                ApiError::bad_request(format!("bad precision {prec:?}"))
            })?,
            streams: parse_count(streams, "streams")?,
        },
        ["PLAN", objective, streams, n] => Request::Plan {
            objective: parse_objective(objective).ok_or_else(|| {
                ApiError::bad_request(format!("bad objective {objective:?}"))
            })?,
            streams: parse_count(streams, "streams")?,
            n: parse_count(n, "size")?,
            // The legacy command has no precision slot; FP8 is the
            // paper's serving default.
            precision: Precision::Fp8,
        },
        ["SPARSITY", n, streams] => Request::Sparsity {
            n: parse_count(n, "size")?,
            streams: parse_count(streams, "streams")?,
        },
        ["RUN", entry] => Request::Run { entry: entry.to_string() },
        ["LIST"] => Request::ListExperiments,
        ["CONFIG"] => Request::Config,
        ["STATS"] => Request::Stats,
        ["BACKENDS"] => Request::Backends,
        _ => {
            return Err(ApiError::new(
                ErrorCode::UnknownType,
                "unknown command (try SIM/PLAN/SPARSITY/RUN/LIST/CONFIG/\
                 STATS/BACKENDS/QUIT or a JSON request line)",
            ))
        }
    };
    Ok(LegacyCommand::Request(req))
}

fn parse_count(s: &str, what: &str) -> Result<usize, ApiError> {
    s.parse().map_err(|_| {
        ApiError::bad_request(format!("bad {what}: {s:?}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_roundtrip() {
        for c in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(c.as_str()), Some(c));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn precision_wire_names_roundtrip() {
        for p in [
            Precision::F64,
            Precision::F32,
            Precision::F16,
            Precision::Bf16,
            Precision::Fp8,
            Precision::Bf8,
        ] {
            assert_eq!(Precision::parse(precision_wire_name(p)), Some(p));
        }
    }

    #[test]
    fn request_envelope_carries_version_and_id() {
        let req = Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        };
        let v = req.to_json(Some(7));
        assert_eq!(v.get("v"), Some(&Json::Num(1.0)));
        assert_eq!(v.get("id"), Some(&Json::Num(7.0)));
        assert_eq!(v.get("type").unwrap().as_str(), Some("sim"));
        let (back, id) = Request::from_json(&v).unwrap();
        assert_eq!(back, req);
        assert_eq!(id, Some(7));
    }

    #[test]
    fn legacy_lines_desugar_to_typed_requests() {
        assert_eq!(
            parse_legacy("SIM 512 fp8 4").unwrap(),
            LegacyCommand::Request(Request::Sim {
                n: 512,
                precision: Precision::Fp8,
                streams: 4,
            })
        );
        assert_eq!(parse_legacy("quit").unwrap(), LegacyCommand::Quit);
        let err = parse_legacy("SIM abc fp8 4").unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        assert!(err.message.contains("bad size"));
        let err = parse_legacy("FROB 1").unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownType);
    }

    #[test]
    fn unknown_fields_and_versions_are_typed_errors() {
        let v = Json::parse(
            r#"{"v":1,"type":"sim","n":512,"precision":"fp8",
                "streams":4,"bogus":1}"#,
        )
        .unwrap();
        let (err, _) = Request::from_json(&v).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownField);
        assert!(err.message.contains("bogus"));

        let v = Json::parse(r#"{"v":2,"id":9,"type":"config"}"#).unwrap();
        let (err, id) = Request::from_json(&v).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadVersion);
        assert_eq!(id, Some(9), "id must be salvaged for the error reply");

        let v = Json::parse(r#"{"type":"config"}"#).unwrap();
        let (err, _) = Request::from_json(&v).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadVersion);
    }

    #[test]
    fn cache_envelope_flag_defaults_true_and_roundtrips_false() {
        let req = Request::Sparsity { n: 512, streams: 4 };
        let (_, env) = Request::decode(&req.to_json(Some(3))).unwrap();
        assert_eq!(
            env,
            RequestEnvelope { id: Some(3), cache: true, backend: None }
        );

        let wire = req.to_json_opts(Some(3), false).to_string();
        assert!(wire.contains(r#""cache":false"#), "{wire}");
        let (back, env) =
            Request::decode(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, req);
        assert!(!env.cache);
        assert_eq!(back.to_json_opts(env.id, env.cache).to_string(), wire);

        // cache key ignores the envelope entirely.
        assert_eq!(req.cache_key(), back.cache_key());
        assert!(!req.cache_key().contains("cache"));

        let bad = Json::parse(r#"{"v":1,"cache":1,"type":"config"}"#)
            .unwrap();
        let (err, _) = Request::decode(&bad).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn batch_items_are_envelope_less_and_do_not_nest() {
        let batch = Request::Batch {
            items: vec![
                Request::Sparsity { n: 512, streams: 4 },
                Request::Stats,
            ],
        };
        let wire = batch.to_json(Some(1)).to_string();
        let (back, id) =
            Request::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, batch);
        assert_eq!(id, Some(1));

        for (line, needle) in [
            (r#"{"v":1,"type":"batch","items":[]}"#, "must not be empty"),
            (
                r#"{"v":1,"type":"batch","items":[{"type":"batch","items":[{"type":"stats"}]}]}"#,
                "do not nest",
            ),
            (
                r#"{"v":1,"type":"batch","items":[{"v":1,"type":"stats"}]}"#,
                "batch envelope",
            ),
            (
                r#"{"v":1,"type":"batch","items":[{"id":4,"type":"stats"}]}"#,
                "batch envelope",
            ),
            (
                r#"{"v":1,"type":"batch","items":[{"type":"stats","x":1}]}"#,
                "unknown field",
            ),
        ] {
            let (err, _) =
                Request::from_json(&Json::parse(line).unwrap()).unwrap_err();
            assert!(
                err.message.contains(needle),
                "{line} -> {err} (wanted {needle:?})"
            );
        }
    }

    #[test]
    fn legacy_stats_desugars() {
        assert_eq!(
            parse_legacy("STATS").unwrap(),
            LegacyCommand::Request(Request::Stats)
        );
        assert_eq!(
            parse_legacy("BACKENDS").unwrap(),
            LegacyCommand::Request(Request::Backends)
        );
    }

    #[test]
    fn backend_envelope_key_roundtrips_and_unknown_ids_are_typed() {
        use crate::backend::BackendId;
        let req = Request::Sim {
            n: 512,
            precision: Precision::Fp8,
            streams: 4,
        };
        let env = RequestEnvelope {
            id: Some(2),
            cache: true,
            backend: Some(BackendId::Analytic),
        };
        let wire = req.to_json_env(&env).to_string();
        assert!(wire.contains(r#""backend":"analytic""#), "{wire}");
        let (back, got) =
            Request::decode(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, req);
        assert_eq!(got, env);
        assert_eq!(back.to_json_env(&got).to_string(), wire);
        // The default (no backend) is omitted: canonical bytes stay
        // identical to the pre-backend wire form.
        assert!(!req.to_json(Some(2)).to_string().contains("backend"));
        // The cache key never carries envelope keys.
        assert!(!req.cache_key().contains("backend"));

        // Unknown ids are the typed unknown_backend error, salvaging
        // the envelope id for the reply.
        let bad = r#"{"v":1,"id":9,"backend":"slide_rule","type":"config"}"#;
        let (err, id) =
            Request::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::UnknownBackend);
        assert!(err.message.contains("slide_rule"), "{err}");
        assert_eq!(id, Some(9));

        let bad = r#"{"v":1,"backend":7,"type":"config"}"#;
        let (err, _) =
            Request::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);

        // Responses never carry the key.
        let resp =
            r#"{"v":1,"backend":"des","type":"config","config":{}}"#;
        let err =
            Response::from_json(&Json::parse(resp).unwrap()).unwrap_err();
        assert!(err.message.contains("request-envelope"), "{err}");
    }
}
