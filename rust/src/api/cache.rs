//! Canonical-key result cache behind [`super::Service`] (DESIGN.md
//! §6.5, `docs/serving.md` is the operator guide).
//!
//! The paper's product is *practical guidance* — occupancy thresholds,
//! fairness-vs-streams trade-offs, context-dependent sparsity decisions
//! — that clients ask for repeatedly with the *same* configurations.
//! Every cacheable request is a pure function of the service's
//! immutable configuration, so the service memoizes it: the request's
//! canonical wire form ([`super::Request::cache_key`] — sorted keys, no
//! envelope, enum-normalized spellings) is the key, and the stored
//! [`Response`] re-serializes byte-identically to a cold run because
//! the wire encoding itself is deterministic. Scenario-backed requests
//! (the v1 simulator trio, `scenario` sweeps, and job points) memoize
//! at **sweep-point granularity** under the canonical single-point
//! spec ([`super::scenario::ScenarioSpec::at`]), so a sweep, its v1
//! equivalents, and an async job all share entries.
//!
//! The cache is bounded by an entry cap and an approximate byte cap
//! ([`CachePolicy`]); when either is exceeded the least-recently-used
//! entry is evicted. Hit/miss/eviction/size counters ([`CacheStats`])
//! surface through the `stats` request, so a load test can *prove* a
//! hot request never re-entered the DES engine instead of inferring it
//! from latency.
//!
//! What is never cached: `run` (real PJRT execution), `repro` of a
//! registry entry not flagged deterministic (see
//! [`crate::experiments::ExperimentSpec`]), error responses, `stats`
//! itself, and anything sent with the `"cache":false` envelope escape
//! hatch (or served by a `--no-cache` instance) for measurement runs.

use super::protocol::Response;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// Sizing and on/off switch for a [`ResultCache`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CachePolicy {
    /// Master switch. Disabled caches store nothing and count nothing
    /// (the `--no-cache` serving mode for measurement runs).
    pub enabled: bool,
    /// Maximum number of cached responses (LRU-evicted beyond this).
    pub max_entries: usize,
    /// Approximate byte budget: each entry is charged its key length
    /// plus its compact wire serialization length.
    pub max_bytes: usize,
}

impl Default for CachePolicy {
    fn default() -> CachePolicy {
        CachePolicy {
            enabled: true,
            max_entries: 1024,
            max_bytes: 64 << 20,
        }
    }
}

impl CachePolicy {
    /// The `--no-cache` policy: every request runs cold.
    pub fn disabled() -> CachePolicy {
        CachePolicy { enabled: false, ..CachePolicy::default() }
    }
}

/// A point-in-time snapshot of cache counters, surfaced on the wire by
/// the `stats` request (`cache_*` fields).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to a cold execution (uncacheable and
    /// cache-bypassing requests count neither hits nor misses).
    pub misses: u64,
    /// Entries removed by the LRU bound (not by replacement).
    pub evictions: u64,
    /// Live entries right now.
    pub entries: u64,
    /// Approximate bytes held right now (keys + wire-form responses).
    pub bytes: u64,
    /// The policy's entry cap.
    pub max_entries: u64,
    /// The policy's byte cap.
    pub max_bytes: u64,
    /// Whether the cache is enabled at all.
    pub enabled: bool,
}

struct Slot {
    // Arc so a hit only bumps a refcount under the lock; the deep
    // clone the caller receives happens after the guard drops.
    resp: Arc<Response>,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<String, Slot>,
    bytes: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// A bounded, thread-safe LRU of canonical request key → response.
///
/// Exact LRU: every hit refreshes the entry's recency; eviction always
/// removes the least-recently-used entry. Shared by reference from
/// every connection thread of a serving instance (interior `Mutex`; the
/// critical sections are map operations only — cold executions never
/// run under the lock).
pub struct ResultCache {
    policy: CachePolicy,
    inner: Mutex<Inner>,
}

impl ResultCache {
    /// An empty cache under `policy`.
    pub fn new(policy: CachePolicy) -> ResultCache {
        ResultCache { policy, inner: Mutex::new(Inner::default()) }
    }

    /// Whether the policy enables caching at all.
    pub fn enabled(&self) -> bool {
        self.policy.enabled
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // Counters and map stay usable even if a panic poisoned the
        // lock mid-update; stale recency is acceptable, losing the
        // serving cache is not.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Look `key` up, refreshing its recency. Counts a hit or a miss;
    /// returns `None` without counting when the cache is disabled. The
    /// lock is held only for the map touch — the returned deep clone is
    /// made after the guard drops, so concurrent hits do not serialize
    /// on response size.
    pub fn get(&self, key: &str) -> Option<Response> {
        if !self.policy.enabled {
            return None;
        }
        let hit = {
            let mut guard = self.lock();
            let inner = &mut *guard;
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(slot) = inner.map.get_mut(key) {
                slot.last_used = tick;
                let arc = Arc::clone(&slot.resp);
                inner.hits += 1;
                Some(arc)
            } else {
                inner.misses += 1;
                None
            }
        };
        hit.map(|arc| (*arc).clone())
    }

    /// Store `resp` under `key`, then evict LRU entries until both caps
    /// hold. Replacing an existing key (two threads racing the same
    /// cold request) is not an eviction. An entry alone larger than the
    /// byte cap is not stored at all. The clone and the byte-accounting
    /// serialization happen before the lock is taken.
    pub fn insert(&self, key: String, resp: &Response) {
        if !self.policy.enabled {
            return;
        }
        let cost = key.len() + resp.to_json(None).to_string().len();
        if cost > self.policy.max_bytes {
            return;
        }
        let stored = Arc::new(resp.clone());
        let mut guard = self.lock();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        let slot = Slot { resp: stored, bytes: cost, last_used: tick };
        if let Some(old) = inner.map.insert(key, slot) {
            inner.bytes -= old.bytes;
        }
        inner.bytes += cost;
        // The fresh entry carries the newest tick, so it is never the
        // LRU victim unless it is the only entry — excluded by the
        // single-entry cost pre-check and the >=1 cap normalization.
        let max_entries = self.policy.max_entries.max(1);
        while inner.map.len() > max_entries
            || inner.bytes > self.policy.max_bytes
        {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(s) = inner.map.remove(&k) {
                        inner.bytes -= s.bytes;
                    }
                    inner.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> CacheStats {
        let guard = self.lock();
        CacheStats {
            hits: guard.hits,
            misses: guard.misses,
            evictions: guard.evictions,
            entries: guard.map.len() as u64,
            bytes: guard.bytes as u64,
            max_entries: self.policy.max_entries as u64,
            max_bytes: self.policy.max_bytes as u64,
            enabled: self.policy.enabled,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn resp(tag: &str) -> Response {
        Response::Config { config: Json::Str(tag.to_string()) }
    }

    fn policy(max_entries: usize, max_bytes: usize) -> CachePolicy {
        CachePolicy { enabled: true, max_entries, max_bytes }
    }

    #[test]
    fn hit_miss_and_replace_accounting() {
        let c = ResultCache::new(policy(8, 1 << 20));
        assert_eq!(c.get("a"), None);
        c.insert("a".into(), &resp("one"));
        assert_eq!(c.get("a"), Some(resp("one")));
        // Replacement swaps the value without an eviction and without
        // double-charging bytes.
        c.insert("a".into(), &resp("two"));
        assert_eq!(c.get("a"), Some(resp("two")));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 0));
        assert_eq!(s.entries, 1);
        let one_entry_bytes = s.bytes;
        c.insert("b".into(), &resp("two"));
        assert_eq!(c.stats().bytes, 2 * one_entry_bytes);
    }

    #[test]
    fn evicts_exactly_the_least_recently_used_entry() {
        let c = ResultCache::new(policy(2, 1 << 20));
        c.insert("a".into(), &resp("a"));
        c.insert("b".into(), &resp("b"));
        // Touch "a" so "b" becomes LRU, then overflow the entry cap.
        assert!(c.get("a").is_some());
        c.insert("c".into(), &resp("c"));
        assert_eq!(c.get("b"), None, "LRU entry must be the victim");
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn byte_cap_evicts_and_oversized_entries_are_skipped() {
        let small = resp("x");
        let cost = "k0".len() + small.to_json(None).to_string().len();
        // Room for exactly two entries of this shape.
        let c = ResultCache::new(policy(64, 2 * cost));
        c.insert("k0".into(), &small);
        c.insert("k1".into(), &small);
        assert_eq!(c.stats().evictions, 0);
        c.insert("k2".into(), &small);
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 2 * cost as u64);
        // An entry alone exceeding the cap is refused outright.
        let big = Response::Config {
            config: Json::Str("y".repeat(4 * cost)),
        };
        c.insert("k3".into(), &big);
        assert_eq!(c.get("k3"), None);
    }

    #[test]
    fn disabled_cache_stores_and_counts_nothing() {
        let c = ResultCache::new(CachePolicy::disabled());
        c.insert("a".into(), &resp("a"));
        assert_eq!(c.get("a"), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        assert!(!s.enabled);
    }
}
