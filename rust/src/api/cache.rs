//! Canonical-key result cache behind [`super::Service`] (DESIGN.md
//! §6.5, `docs/serving.md` is the operator guide,
//! `docs/performance.md` covers tuning).
//!
//! The paper's product is *practical guidance* — occupancy thresholds,
//! fairness-vs-streams trade-offs, context-dependent sparsity decisions
//! — that clients ask for repeatedly with the *same* configurations.
//! Every cacheable request is a pure function of the service's
//! immutable configuration, so the service memoizes it: the request's
//! canonical wire form ([`super::Request::cache_key`] — sorted keys, no
//! envelope, enum-normalized spellings) is the key, and the stored
//! [`Response`] re-serializes byte-identically to a cold run because
//! the wire encoding itself is deterministic. Scenario-backed requests
//! (the v1 simulator trio, `scenario` sweeps, and job points) memoize
//! at **sweep-point granularity** under the canonical single-point
//! spec ([`super::scenario::ScenarioSpec::at`]), so a sweep, its v1
//! equivalents, and an async job all share entries.
//!
//! ## Sharding
//!
//! The map is split into N hash-sharded segments (FNV-1a over the key;
//! N is a power of two, defaulting to the machine's parallelism —
//! [`CachePolicy::shards`]). A **hit takes only the owning shard's read
//! path**: a shared `RwLock` read guard plus an atomically-bumped LRU
//! clock, so concurrent hits — even on the *same* hot key — never
//! contend with each other, the way the paper's ACEs serve independent
//! queues without a global lock. Writes take the owning shard's write
//! lock only. Recency is a global monotone clock (`AtomicU64`), so LRU
//! order is comparable *across* shards.
//!
//! The caps stay **global**: one entry cap and one approximate byte cap
//! ([`CachePolicy`]) over the whole cache, enforced by evicting the
//! globally least-recently-used entry (a read-only scan across shards
//! picks the victim; only its owning shard takes a write lock to remove
//! it). Evictors serialize on a small mutex so concurrent
//! over-cap inserts cannot double-evict, but that mutex is never
//! touched on the hit path. Hit/miss/eviction counters are per-shard
//! atomics summed on demand, so [`CacheStats`] keeps the exact counter
//! semantics of the unsharded cache, and a load test can *prove* a hot
//! request never re-entered the DES engine instead of inferring it from
//! latency.
//!
//! What is never cached: `run` (real PJRT execution), `repro` of a
//! registry entry not flagged deterministic (see
//! [`crate::experiments::ExperimentSpec`]), error responses, `stats`
//! itself, and anything sent with the `"cache":false` envelope escape
//! hatch (or served by a `--no-cache` instance) for measurement runs.

use super::protocol::Response;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Sizing and on/off switch for a [`ResultCache`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CachePolicy {
    /// Master switch. Disabled caches store nothing and count nothing
    /// (the `--no-cache` serving mode for measurement runs).
    pub enabled: bool,
    /// Maximum number of cached responses across all shards
    /// (LRU-evicted beyond this).
    pub max_entries: usize,
    /// Approximate byte budget across all shards: each entry is charged
    /// its key length plus its compact wire serialization length.
    pub max_bytes: usize,
    /// Number of hash shards. `0` (the default) sizes to the machine's
    /// available parallelism; any other value is rounded up to the next
    /// power of two. Sharding changes contention only — caps, counters,
    /// LRU order, and responses are byte-identical at any shard count.
    pub shards: usize,
}

impl Default for CachePolicy {
    fn default() -> CachePolicy {
        CachePolicy {
            enabled: true,
            max_entries: 1024,
            max_bytes: 64 << 20,
            shards: 0,
        }
    }
}

impl CachePolicy {
    /// The `--no-cache` policy: every request runs cold.
    pub fn disabled() -> CachePolicy {
        CachePolicy { enabled: false, ..CachePolicy::default() }
    }
}

/// A point-in-time snapshot of cache counters, surfaced on the wire by
/// the `stats` request (`cache_*` fields). Counters are summed across
/// shards; under a quiescent cache they are exact.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to a cold execution (uncacheable and
    /// cache-bypassing requests count neither hits nor misses).
    pub misses: u64,
    /// Entries removed by the LRU bound (not by replacement).
    pub evictions: u64,
    /// Live entries right now.
    pub entries: u64,
    /// Approximate bytes held right now (keys + wire-form responses).
    pub bytes: u64,
    /// The policy's entry cap.
    pub max_entries: u64,
    /// The policy's byte cap.
    pub max_bytes: u64,
    /// Whether the cache is enabled at all.
    pub enabled: bool,
}

struct Slot {
    // Arc so a hit only bumps a refcount under the shard's read lock;
    // the deep clone the caller receives happens after the guard drops.
    resp: Arc<Response>,
    bytes: usize,
    // Atomic so a *read*-locked hit can refresh recency without
    // upgrading to the write lock (monotone via fetch_max).
    last_used: AtomicU64,
}

/// One hash shard: its slice of the map plus its share of the hit/miss/
/// eviction counters (summed by [`ResultCache::stats`]).
#[derive(Default)]
struct Shard {
    map: RwLock<HashMap<String, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

/// A bounded, thread-safe, hash-sharded LRU of canonical request key →
/// response.
///
/// Exact LRU under a global clock: every hit refreshes the entry's
/// recency; eviction removes the globally least-recently-used entry.
/// Shared by reference from every connection of a serving instance.
/// Hits touch only the owning shard's `RwLock` read path (reads never
/// contend with reads); cold executions never run under any lock.
pub struct ResultCache {
    policy: CachePolicy,
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    mask: usize,
    /// Global LRU clock; bumped once per get/insert.
    clock: AtomicU64,
    /// Live entries across all shards (kept exact under shard locks).
    entries: AtomicUsize,
    /// Approximate bytes across all shards.
    bytes: AtomicUsize,
    /// Serializes evictors so concurrent over-cap inserts cannot
    /// double-evict. Never touched on the hit path.
    evict: Mutex<()>,
}

/// Round `n` up to the next power of two, minimum 1.
fn pow2_at_least(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// FNV-1a: tiny, allocation-free, and good enough to spread canonical
/// JSON keys across a handful of shards. Shared with the cluster hash
/// ring ([`crate::cluster`]), which routes the *same* canonical
/// per-point cache keys across workers.
pub(crate) fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

impl ResultCache {
    /// An empty cache under `policy`.
    pub fn new(policy: CachePolicy) -> ResultCache {
        let n = if policy.shards == 0 {
            pow2_at_least(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            )
        } else {
            pow2_at_least(policy.shards)
        };
        let shards = (0..n).map(|_| Shard::default()).collect();
        ResultCache {
            policy,
            shards,
            mask: n - 1,
            clock: AtomicU64::new(0),
            entries: AtomicUsize::new(0),
            bytes: AtomicUsize::new(0),
            evict: Mutex::new(()),
        }
    }

    /// Whether the policy enables caching at all.
    pub fn enabled(&self) -> bool {
        self.policy.enabled
    }

    /// The resolved shard count (policy value normalized to a power of
    /// two, or the machine's parallelism for `shards: 0`).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, key: &str) -> &Shard {
        &self.shards[fnv1a(key) as usize & self.mask]
    }

    // Counters and map stay usable even if a panic poisoned a lock
    // mid-update; stale recency is acceptable, losing the serving
    // cache is not.
    fn read_map<'a>(
        shard: &'a Shard,
    ) -> RwLockReadGuard<'a, HashMap<String, Slot>> {
        shard.map.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write_map<'a>(
        shard: &'a Shard,
    ) -> RwLockWriteGuard<'a, HashMap<String, Slot>> {
        shard.map.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Look `key` up, refreshing its recency. Counts a hit or a miss;
    /// returns `None` without counting when the cache is disabled. Only
    /// the owning shard's **read** lock is taken — concurrent hits
    /// (same key or not) proceed in parallel — and the returned deep
    /// clone is made after the guard drops, so hits do not serialize on
    /// response size.
    pub fn get(&self, key: &str) -> Option<Response> {
        if !self.policy.enabled {
            return None;
        }
        let tick = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        let shard = self.shard_of(key);
        let hit = {
            let map = Self::read_map(shard);
            match map.get(key) {
                Some(slot) => {
                    slot.last_used.fetch_max(tick, Ordering::Relaxed);
                    shard.hits.fetch_add(1, Ordering::Relaxed);
                    Some(Arc::clone(&slot.resp))
                }
                None => {
                    shard.misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
            }
        };
        hit.map(|arc| (*arc).clone())
    }

    /// Store `resp` under `key`, then evict globally-LRU entries until
    /// both caps hold. Replacing an existing key (two threads racing
    /// the same cold request) is not an eviction. An entry alone larger
    /// than the byte cap is not stored at all. The clone and the
    /// byte-accounting serialization happen before any lock is taken;
    /// only the owning shard's write lock is held for the map touch.
    pub fn insert(&self, key: String, resp: &Response) {
        if !self.policy.enabled {
            return;
        }
        let cost = key.len() + resp.to_json(None).to_string().len();
        if cost > self.policy.max_bytes {
            return;
        }
        let stored = Arc::new(resp.clone());
        let tick = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        let shard = self.shard_of(&key);
        {
            let mut map = Self::write_map(shard);
            let slot = Slot {
                resp: stored,
                bytes: cost,
                last_used: AtomicU64::new(tick),
            };
            match map.insert(key, slot) {
                Some(old) => {
                    self.bytes.fetch_sub(old.bytes, Ordering::Relaxed);
                }
                None => {
                    self.entries.fetch_add(1, Ordering::Relaxed);
                }
            }
            self.bytes.fetch_add(cost, Ordering::Relaxed);
        }
        self.evict_until_within_caps();
    }

    /// Evict globally least-recently-used entries until both caps hold.
    /// Victim selection scans every shard under its *read* lock (hits
    /// stay unblocked); removal takes only the victim's shard write
    /// lock. The evictor mutex keeps concurrent over-cap inserts from
    /// racing each other past the caps.
    fn evict_until_within_caps(&self) {
        let max_entries = self.policy.max_entries.max(1);
        if self.entries.load(Ordering::Relaxed) <= max_entries
            && self.bytes.load(Ordering::Relaxed) <= self.policy.max_bytes
        {
            return;
        }
        let _evictor = self.evict.lock().unwrap_or_else(|e| e.into_inner());
        while self.entries.load(Ordering::Relaxed) > max_entries
            || self.bytes.load(Ordering::Relaxed) > self.policy.max_bytes
        {
            // The freshest entry carries the newest tick, so it is
            // never the victim unless it is the only entry — excluded
            // by the single-entry cost pre-check and the >=1 cap
            // normalization.
            let mut victim: Option<(usize, String, u64)> = None;
            for (i, shard) in self.shards.iter().enumerate() {
                let map = Self::read_map(shard);
                for (k, slot) in map.iter() {
                    let used = slot.last_used.load(Ordering::Relaxed);
                    let older = match &victim {
                        Some((_, _, best)) => used < *best,
                        None => true,
                    };
                    if older {
                        victim = Some((i, k.clone(), used));
                    }
                }
            }
            match victim {
                Some((i, key, _)) => {
                    let shard = &self.shards[i];
                    let mut map = Self::write_map(shard);
                    if let Some(slot) = map.remove(&key) {
                        self.bytes.fetch_sub(slot.bytes, Ordering::Relaxed);
                        self.entries.fetch_sub(1, Ordering::Relaxed);
                        shard.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
    }

    /// Snapshot the counters (per-shard tallies summed, global sizes
    /// read once).
    pub fn stats(&self) -> CacheStats {
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut evictions = 0u64;
        for shard in &self.shards {
            hits += shard.hits.load(Ordering::Relaxed);
            misses += shard.misses.load(Ordering::Relaxed);
            evictions += shard.evictions.load(Ordering::Relaxed);
        }
        CacheStats {
            hits,
            misses,
            evictions,
            entries: self.entries.load(Ordering::Relaxed) as u64,
            bytes: self.bytes.load(Ordering::Relaxed) as u64,
            max_entries: self.policy.max_entries as u64,
            max_bytes: self.policy.max_bytes as u64,
            enabled: self.policy.enabled,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn resp(tag: &str) -> Response {
        Response::Config { config: Json::Str(tag.to_string()) }
    }

    fn policy(max_entries: usize, max_bytes: usize) -> CachePolicy {
        CachePolicy {
            enabled: true,
            max_entries,
            max_bytes,
            ..CachePolicy::default()
        }
    }

    #[test]
    fn hit_miss_and_replace_accounting() {
        let c = ResultCache::new(policy(8, 1 << 20));
        assert_eq!(c.get("a"), None);
        c.insert("a".into(), &resp("one"));
        assert_eq!(c.get("a"), Some(resp("one")));
        // Replacement swaps the value without an eviction and without
        // double-charging bytes.
        c.insert("a".into(), &resp("two"));
        assert_eq!(c.get("a"), Some(resp("two")));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 0));
        assert_eq!(s.entries, 1);
        let one_entry_bytes = s.bytes;
        c.insert("b".into(), &resp("two"));
        assert_eq!(c.stats().bytes, 2 * one_entry_bytes);
    }

    #[test]
    fn evicts_exactly_the_least_recently_used_entry() {
        let c = ResultCache::new(policy(2, 1 << 20));
        c.insert("a".into(), &resp("a"));
        c.insert("b".into(), &resp("b"));
        // Touch "a" so "b" becomes LRU, then overflow the entry cap.
        assert!(c.get("a").is_some());
        c.insert("c".into(), &resp("c"));
        assert_eq!(c.get("b"), None, "LRU entry must be the victim");
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
    }

    #[test]
    fn byte_cap_evicts_and_oversized_entries_are_skipped() {
        let small = resp("x");
        let cost = "k0".len() + small.to_json(None).to_string().len();
        // Room for exactly two entries of this shape.
        let c = ResultCache::new(policy(64, 2 * cost));
        c.insert("k0".into(), &small);
        c.insert("k1".into(), &small);
        assert_eq!(c.stats().evictions, 0);
        c.insert("k2".into(), &small);
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 2 * cost as u64);
        // An entry alone exceeding the cap is refused outright.
        let big = Response::Config {
            config: Json::Str("y".repeat(4 * cost)),
        };
        c.insert("k3".into(), &big);
        assert_eq!(c.get("k3"), None);
    }

    #[test]
    fn disabled_cache_stores_and_counts_nothing() {
        let c = ResultCache::new(CachePolicy::disabled());
        c.insert("a".into(), &resp("a"));
        assert_eq!(c.get("a"), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        assert!(!s.enabled);
    }

    #[test]
    fn shard_count_resolution() {
        let one = ResultCache::new(CachePolicy {
            shards: 1,
            ..policy(8, 1 << 20)
        });
        assert_eq!(one.shard_count(), 1);
        let rounded = ResultCache::new(CachePolicy {
            shards: 5,
            ..policy(8, 1 << 20)
        });
        assert_eq!(rounded.shard_count(), 8);
        let auto = ResultCache::new(policy(8, 1 << 20));
        assert!(auto.shard_count().is_power_of_two());
        assert!(auto.shard_count() >= 1);
    }

    /// Behavior must be byte- and counter-identical at any shard
    /// count: the same key sequence against 1 shard and 8 shards
    /// yields identical responses, stats, and the same global-LRU
    /// victim even when keys land on different shards.
    #[test]
    fn global_lru_semantics_hold_across_shard_counts() {
        for shards in [1usize, 2, 8] {
            let c = ResultCache::new(CachePolicy {
                shards,
                ..policy(2, 1 << 20)
            });
            c.insert("alpha".into(), &resp("alpha"));
            c.insert("beta".into(), &resp("beta"));
            assert!(c.get("alpha").is_some());
            c.insert("gamma".into(), &resp("gamma"));
            assert_eq!(
                c.get("beta"),
                None,
                "{shards}-shard cache must evict the global LRU"
            );
            assert_eq!(c.get("alpha"), Some(resp("alpha")));
            assert_eq!(c.get("gamma"), Some(resp("gamma")));
            let s = c.stats();
            assert_eq!((s.hits, s.misses, s.evictions), (3, 1, 1));
            assert_eq!(s.entries, 2);
        }
    }

    /// Concurrent hits on one hot key all succeed with identical
    /// bytes and sum to an exact hit count (the read-path contract the
    /// serve-layer stress test exercises end to end).
    #[test]
    fn concurrent_hot_key_hits_count_exactly() {
        let c = std::sync::Arc::new(ResultCache::new(CachePolicy {
            shards: 4,
            ..policy(64, 1 << 20)
        }));
        c.insert("hot".into(), &resp("hot"));
        let threads = 8;
        let per = 50;
        let mut joins = Vec::new();
        for _ in 0..threads {
            let c = std::sync::Arc::clone(&c);
            joins.push(std::thread::spawn(move || {
                for _ in 0..per {
                    assert_eq!(c.get("hot"), Some(resp("hot")));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let s = c.stats();
        assert_eq!(s.hits, (threads * per) as u64);
        assert_eq!(s.misses, 0);
        assert_eq!(s.entries, 1);
    }
}
