//! `mi300a-char serve` — a thin TCP transport over [`crate::api`].
//!
//! Framing: one message per line. A line starting with `{` is a
//! versioned JSON request (DESIGN.md §6); its optional `id` is echoed on
//! the response so clients can pipeline many requests on one
//! connection, its optional `"cache":false` envelope flag bypasses the
//! service's result cache, and a `batch` request answers its items in
//! one envelope. Any other non-empty line goes through the legacy text
//! shim (`SIM`/`PLAN`/`SPARSITY`/`RUN`/`LIST`/`CONFIG`/`STATS`/`QUIT`),
//! which desugars into the same typed requests — the response line is
//! byte-identical to the JSON form without an `id` (enforced by
//! tests/serve_integration.rs).
//!
//! All business logic lives in [`crate::api::Service`]: this module
//! only accepts connections, frames lines, and serializes responses.
//! Repeat requests across *all* connections share the service's result
//! cache ([`crate::api::cache`]); start with [`serve_with`] and
//! [`crate::api::CachePolicy::disabled`] (the CLI's `--no-cache`) for
//! measurement runs.
//!
//! ## Concurrency
//!
//! One thread per connection over a shared `Arc<Service>`:
//! `sim`/`plan`/`sparsity` requests are pure functions of the immutable
//! config and scale across cores, the way the paper's ACEs scale
//! independent streams. The one non-`Sync` resource — the PJRT
//! executor — is isolated inside the service on a single mpsc worker
//! thread, so `run` requests serialize through it (exactly like
//! launches serialize through a command lane) without blocking the
//! simulator paths. Responses are deterministic per request for a fixed
//! config/seed, so concurrent clients observe byte-identical answers to
//! a single client.

use crate::api::{CachePolicy, LegacyCommand, Request, Response, Service};
use crate::config::Config;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;

/// Serve on `addr` (e.g. "127.0.0.1:0") with the default cache policy;
/// returns after `max_conns` connections have been accepted and fully
/// served (None = forever). Prints the bound address on stdout so
/// callers/tests can discover the ephemeral port.
pub fn serve(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
) -> std::io::Result<()> {
    serve_with(cfg, addr, max_conns, CachePolicy::default())
}

/// [`serve`] with an explicit result-cache policy (`--no-cache` passes
/// [`CachePolicy::disabled`]).
pub fn serve_with(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("serving on {}", listener.local_addr()?);
    let svc = Arc::new(Service::with_cache_policy(cfg, policy));

    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut served = 0usize;
    for conn in listener.incoming() {
        let stream = conn?;
        let svc = Arc::clone(&svc);
        conns.push(thread::spawn(move || {
            if let Err(e) = handle(&svc, stream) {
                eprintln!("connection error: {e}");
            }
        }));
        // Reap finished connection threads so a long-lived server does
        // not accumulate handles.
        conns.retain(|h| !h.is_finished());
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
    // Dropping the service (last Arc) shuts its executor worker down.
    Ok(())
}

/// One connection: frame lines, route through the service, write one
/// response line per request line.
fn handle(svc: &Service, stream: TcpStream) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        if text.starts_with('{') {
            let (resp, id) = dispatch_json(svc, text);
            writeln!(writer, "{}", resp.to_json(id))?;
        } else {
            match crate::api::parse_legacy(text) {
                Ok(LegacyCommand::Quit) => break,
                Ok(LegacyCommand::Request(req)) => {
                    writeln!(writer, "{}", svc.handle(&req).to_json(None))?
                }
                Err(e) => writeln!(
                    writer,
                    "{}",
                    Response::from(e).to_json(None)
                )?,
            }
        }
    }
    Ok(())
}

/// Decode one JSON request line and route it, honoring the envelope's
/// `cache` flag; decode failures become typed error responses, still
/// tagged with the request's `id` whenever the envelope was readable
/// enough to salvage it.
fn dispatch_json(svc: &Service, text: &str) -> (Response, Option<u64>) {
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (
                Response::from(crate::api::ApiError::bad_request(format!(
                    "unparseable request: {e}"
                ))),
                None,
            )
        }
    };
    match Request::decode(&v) {
        Ok((req, env)) => (svc.handle_opts(&req, env.cache), env.id),
        Err((e, id)) => (Response::from(e), id),
    }
}
