//! `mi300a-char serve` — a thin TCP transport over [`crate::api`].
//!
//! Framing: one message per line. A line starting with `{` is a
//! versioned JSON request (DESIGN.md §6); its optional `id` is echoed on
//! the response so clients can pipeline many requests on one
//! connection, its optional `"cache":false` envelope flag bypasses the
//! service's result cache, its optional `"backend"` envelope key
//! selects the execution backend for scenario-backed requests
//! (DESIGN.md §6.8; `serve --backend` / [`serve_opts`] set the
//! instance default), and a `batch` request answers its items in one
//! envelope. Any other non-empty line goes through the legacy text
//! shim (`SIM`/`PLAN`/`SPARSITY`/`RUN`/`LIST`/`CONFIG`/`STATS`/
//! `BACKENDS`/`QUIT`), which desugars into the same typed requests —
//! the response line is byte-identical to the JSON form without an
//! `id` (enforced by tests/serve_integration.rs).
//!
//! ## Progress push (DESIGN.md §6.7)
//!
//! A top-level `submit` with `"progress":true` registers a watcher on
//! the job atomically with the enqueue. After the `job` response line,
//! the connection pushes `{"type":"progress",…}` frames — each tagged
//! with the *submitting request's* `id` — interleaved with other
//! response lines as the job advances: one snapshot at registration (so
//! at least one frame always arrives), one on the queued→running
//! transition, one per completed sweep point, and one at the terminal
//! state, after which the stream of frames ends. Every line is written
//! atomically under one writer lock, so
//! pipelined responses and frames never interleave mid-line; clients
//! attribute frames by `id` and skip the rest (the native
//! [`crate::api::Client`] does this automatically).
//!
//! All business logic lives in [`crate::api::Service`]: this module
//! only accepts connections, frames lines, and serializes responses.
//! Repeat requests across *all* connections share the service's result
//! cache ([`crate::api::cache`]); start with [`serve_with`] and
//! [`crate::api::CachePolicy::disabled`] (the CLI's `--no-cache`) for
//! measurement runs. Jobs are service-wide too: a job submitted on one
//! connection can be polled, fetched, or cancelled from any other.
//!
//! ## Concurrency
//!
//! One thread per connection over a shared `Arc<Service>`:
//! `sim`/`plan`/`sparsity`/`scenario` requests are pure functions of the
//! immutable config and scale across cores, the way the paper's ACEs
//! scale independent streams. The one non-`Sync` resource — the PJRT
//! executor — is isolated inside the service on a single mpsc worker
//! thread, so `run` requests serialize through it (exactly like
//! launches serialize through a command lane) without blocking the
//! simulator paths. Responses are deterministic per request for a fixed
//! config/seed, so concurrent clients observe byte-identical answers to
//! a single client.

use crate::api::{
    CachePolicy, LegacyCommand, Request, Response, Service,
};
use crate::config::Config;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;

/// Serve on `addr` (e.g. "127.0.0.1:0") with the default cache policy;
/// returns after `max_conns` connections have been accepted and fully
/// served (None = forever). Prints the bound address on stdout so
/// callers/tests can discover the ephemeral port.
pub fn serve(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
) -> std::io::Result<()> {
    serve_with(cfg, addr, max_conns, CachePolicy::default())
}

/// [`serve`] with an explicit result-cache policy (`--no-cache` passes
/// [`CachePolicy::disabled`]).
pub fn serve_with(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
) -> std::io::Result<()> {
    serve_opts(cfg, addr, max_conns, policy, crate::backend::DEFAULT)
}

/// [`serve_with`] plus the instance's default execution backend
/// (the CLI's `serve --backend`; DESIGN.md §6.8) — what answers
/// requests that carry no `"backend"` selector of their own.
pub fn serve_opts(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
    policy: CachePolicy,
    default_backend: crate::backend::BackendId,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("serving on {}", listener.local_addr()?);
    let svc =
        Arc::new(Service::with_default_backend(cfg, policy, default_backend));

    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut served = 0usize;
    for conn in listener.incoming() {
        let stream = conn?;
        let svc = Arc::clone(&svc);
        conns.push(thread::spawn(move || {
            if let Err(e) = handle(&svc, stream) {
                eprintln!("connection error: {e}");
            }
        }));
        // Reap finished connection threads so a long-lived server does
        // not accumulate handles.
        conns.retain(|h| !h.is_finished());
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
    // Dropping the service (last Arc) shuts its executor and job
    // workers down.
    Ok(())
}

/// Write one line under the shared writer lock (responses and pushed
/// progress frames share it, so lines never interleave mid-line).
fn write_line(
    writer: &Arc<Mutex<TcpStream>>,
    v: &Json,
) -> std::io::Result<()> {
    let mut guard = writer.lock().unwrap_or_else(|e| e.into_inner());
    writeln!(&mut *guard, "{v}")
}

/// One connection: frame lines, route through the service, write one
/// response line per request line (plus pushed progress frames for
/// watched submits).
fn handle(svc: &Service, stream: TcpStream) -> std::io::Result<()> {
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let mut pushers: Vec<thread::JoinHandle<()>> = Vec::new();
    for line in reader.lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        if text.starts_with('{') {
            let (resp, id, watch) = dispatch_json(svc, text);
            write_line(&writer, &resp.to_json(id))?;
            if let Some(rx) = watch {
                // Forward progress frames for this submit. The receiver
                // closes at the job's terminal state; a write failure
                // just means the client went away.
                let w = Arc::clone(&writer);
                pushers.push(thread::spawn(move || {
                    while let Ok(view) = rx.recv() {
                        let frame = Response::Progress(view).to_json(id);
                        if write_line(&w, &frame).is_err() {
                            break;
                        }
                    }
                }));
            }
            // Reap pushers whose jobs already finished, so a long-lived
            // connection submitting many watched jobs does not
            // accumulate exited threads.
            pushers.retain(|h| !h.is_finished());
        } else {
            match crate::api::parse_legacy(text) {
                Ok(LegacyCommand::Quit) => break,
                Ok(LegacyCommand::Request(req)) => {
                    write_line(&writer, &svc.handle(&req).to_json(None))?
                }
                Err(e) => {
                    write_line(&writer, &Response::from(e).to_json(None))?
                }
            }
        }
    }
    // Drain the frame forwarders (each ends at its job's terminal
    // state) so "fully served" includes the pushes.
    for h in pushers {
        let _ = h.join();
    }
    Ok(())
}

/// Decode one JSON request line and route it, honoring the envelope's
/// `cache` flag; decode failures become typed error responses, still
/// tagged with the request's `id` whenever the envelope was readable
/// enough to salvage it. A top-level `submit` with `"progress":true`
/// additionally returns the job's watcher receiver for the caller to
/// forward.
fn dispatch_json(
    svc: &Service,
    text: &str,
) -> (
    Response,
    Option<u64>,
    Option<std::sync::mpsc::Receiver<crate::api::JobView>>,
) {
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (
                Response::from(crate::api::ApiError::bad_request(format!(
                    "unparseable request: {e}"
                ))),
                None,
                None,
            )
        }
    };
    match Request::decode(&v) {
        Ok((Request::Submit { spec, progress: true }, env)) => {
            let (resp, rx) = svc.submit_watched(&spec, &env);
            (resp, env.id, rx)
        }
        Ok((req, env)) => (svc.handle_env(&req, &env), env.id, None),
        Err((e, id)) => (Response::from(e), id, None),
    }
}
