//! `mi300a-char serve` — the request loop (L3 leader process).
//!
//! Line protocol over TCP, one request per line, JSON response per
//! line. The loop composes the coordinator's policies with either the
//! simulator (timing questions) or the PJRT runtime (real compute):
//!
//! ```text
//! SIM <n> <precision> <streams>     -> simulated concurrent-run report
//! PLAN <objective> <streams> <n>    -> coordinator execution plan
//! RUN <entry>                       -> execute an AOT artifact (PJRT)
//! SPARSITY <n> <streams>            -> sparsity decision + speedups
//! QUIT
//! ```
//!
//! ## Concurrency
//!
//! The server runs one thread per connection over a shared
//! `Arc<Config>`: `SIM`/`PLAN`/`SPARSITY` requests are pure functions of
//! the (immutable) config and scale across cores, the way the paper's
//! ACE scales independent streams. The one non-`Sync` resource — the
//! PJRT executor — is isolated on a single worker thread behind an mpsc
//! channel, so `RUN` requests serialize through it (exactly like
//! launches serialize through a command lane) without blocking the
//! simulator paths. Responses are deterministic per request for a fixed
//! config/seed, so concurrent clients observe byte-identical answers to
//! a single client (enforced by tests/serve_integration.rs).

use crate::config::Config;
use crate::coordinator::{decide_sparsity, Coordinator, Objective};
use crate::isa::Precision;
use crate::metrics::fairness;
use crate::runtime::{Executor, Manifest};
use crate::sim::{ConcurrencyProfile, Engine, KernelDesc, SparsityMode};
use crate::sparsity::SpeedupModel;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// A request for the executor worker: run `entry`, reply on `reply`.
struct ExecRequest {
    entry: String,
    reply: mpsc::Sender<Result<Json, String>>,
}

/// Handle connection threads use to reach the executor worker. Cloned
/// per connection (mpsc senders are Send + Clone; the executor itself
/// never leaves its worker thread).
type ExecHandle = mpsc::Sender<ExecRequest>;

/// The executor worker: owns the (lazily created) PJRT executor for the
/// whole server lifetime and services RUN requests one at a time. Exits
/// when every handle is dropped.
fn exec_worker(rx: mpsc::Receiver<ExecRequest>) {
    let mut exec: Option<Executor> = None;
    while let Ok(req) = rx.recv() {
        let result = cmd_run(&mut exec, &req.entry);
        // A dropped reply sender just means the client went away.
        let _ = req.reply.send(result);
    }
}

/// Serve on `addr` (e.g. "127.0.0.1:0"); returns after `max_conns`
/// connections have been accepted and fully served (None = forever).
/// Prints the bound address on stdout so callers/tests can discover the
/// ephemeral port.
pub fn serve(
    cfg: Config,
    addr: &str,
    max_conns: Option<usize>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("serving on {}", listener.local_addr()?);
    let cfg = Arc::new(cfg);
    let (exec_tx, exec_rx) = mpsc::channel::<ExecRequest>();
    let worker = thread::spawn(move || exec_worker(exec_rx));

    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut served = 0usize;
    for conn in listener.incoming() {
        let stream = conn?;
        let cfg = Arc::clone(&cfg);
        let exec = exec_tx.clone();
        conns.push(thread::spawn(move || {
            if let Err(e) = handle(&cfg, stream, &exec) {
                eprintln!("connection error: {e}");
            }
        }));
        // Reap finished connection threads so a long-lived server does
        // not accumulate handles.
        conns.retain(|h| !h.is_finished());
        served += 1;
        if let Some(max) = max_conns {
            if served >= max {
                break;
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
    // All connection-held handles are gone; dropping ours shuts the
    // executor worker down.
    drop(exec_tx);
    let _ = worker.join();
    Ok(())
}

fn respond(out: &mut TcpStream, v: Json) -> std::io::Result<()> {
    writeln!(out, "{v}")
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.into()))])
}

fn handle(
    cfg: &Config,
    stream: TcpStream,
    exec: &ExecHandle,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.as_slice() {
            ["QUIT"] | ["quit"] => break,
            ["SIM", n, prec, streams] => {
                let reply = cmd_sim(cfg, n, prec, streams)
                    .unwrap_or_else(|e| err_json(&e));
                respond(&mut writer, reply)?;
            }
            ["PLAN", objective, streams, n] => {
                let reply = cmd_plan(cfg, objective, streams, n)
                    .unwrap_or_else(|e| err_json(&e));
                respond(&mut writer, reply)?;
            }
            ["SPARSITY", n, streams] => {
                let reply = cmd_sparsity(cfg, n, streams)
                    .unwrap_or_else(|e| err_json(&e));
                respond(&mut writer, reply)?;
            }
            ["RUN", entry] => {
                let reply =
                    cmd_run_remote(exec, entry).unwrap_or_else(|e| err_json(&e));
                respond(&mut writer, reply)?;
            }
            [] => {}
            _ => respond(&mut writer, err_json("unknown command"))?,
        }
    }
    Ok(())
}

fn parse_usize(s: &str, what: &str) -> Result<usize, String> {
    s.parse().map_err(|_| format!("bad {what}: {s:?}"))
}

fn cmd_sim(cfg: &Config, n: &str, prec: &str, streams: &str) -> Result<Json, String> {
    let n = parse_usize(n, "size")?;
    let streams = parse_usize(streams, "streams")?.clamp(1, 16);
    let p = Precision::parse(prec).ok_or_else(|| format!("bad precision {prec:?}"))?;
    let engine = Engine::new(cfg, ConcurrencyProfile::ace());
    let ks = vec![KernelDesc::gemm(n, p).with_iters(50); streams];
    // One concurrent simulation per request: the speedup derives from
    // this run plus the (much cheaper) serial solo makespans instead of
    // re-simulating the concurrent set.
    let run = engine.run(&ks, cfg.seed);
    let speedup = engine.serial_makespan_ns(&ks, cfg.seed) / run.makespan_ns;
    Ok(Json::obj(vec![
        ("makespan_ms", Json::Num(run.makespan_ns / 1e6)),
        ("speedup_vs_serial", Json::Num(speedup)),
        ("overlap_efficiency", Json::Num(run.overlap_efficiency)),
        ("fairness", Json::Num(fairness(&run.per_stream_totals()))),
        ("l2_miss", Json::Num(run.l2_miss[0])),
        ("lds_util", Json::Num(run.lds_util)),
    ]))
}

fn cmd_plan(cfg: &Config, objective: &str, streams: &str, n: &str) -> Result<Json, String> {
    let objective = match objective {
        "latency" => Objective::LatencySensitive,
        "throughput" => Objective::ThroughputOriented,
        "isolation" => Objective::StrictIsolation,
        o => return Err(format!("bad objective {o:?}")),
    };
    let streams = parse_usize(streams, "streams")?.clamp(1, 64);
    let n = parse_usize(n, "size")?;
    let pool = vec![KernelDesc::gemm(n, Precision::Fp8).with_iters(100); streams];
    let coord = Coordinator::new(cfg.clone(), objective);
    let plan = coord.plan(&pool, true);
    Ok(Json::obj(vec![
        ("groups", Json::Num(plan.groups.len() as f64)),
        (
            "streams",
            Json::Arr(
                plan.groups
                    .iter()
                    .map(|g| Json::Num(g.streams as f64))
                    .collect(),
            ),
        ),
        (
            "expected_fairness",
            Json::Arr(
                plan.groups
                    .iter()
                    .map(|g| Json::Num(g.expected_fairness))
                    .collect(),
            ),
        ),
        (
            "sparse",
            Json::Bool(plan.groups.iter().any(|g| {
                g.kernels.iter().any(|k| k.sparsity.is_sparse())
            })),
        ),
    ]))
}

fn cmd_sparsity(cfg: &Config, n: &str, streams: &str) -> Result<Json, String> {
    let n = parse_usize(n, "size")?;
    let streams = parse_usize(streams, "streams")?;
    let k = KernelDesc::gemm(n, Precision::Fp8);
    let d = decide_sparsity(&k, streams, true);
    let model = SpeedupModel::new(cfg);
    Ok(Json::obj(vec![
        ("enable", Json::Bool(d.enable)),
        ("reason", Json::Str(format!("{:?}", d.reason))),
        (
            "isolated_speedup",
            Json::Num(model.isolated(&k, SparsityMode::SparseLhs).speedup()),
        ),
        (
            "concurrent_speedup",
            Json::Num(model.concurrent_per_stream(&k, streams.max(2))),
        ),
    ]))
}

/// Connection-side RUN: forwards to the executor worker and waits for
/// its reply (requests queue in arrival order on the channel).
fn cmd_run_remote(exec: &ExecHandle, entry: &str) -> Result<Json, String> {
    let (tx, rx) = mpsc::channel();
    exec.send(ExecRequest { entry: entry.to_string(), reply: tx })
        .map_err(|_| "executor worker unavailable".to_string())?;
    rx.recv().map_err(|_| "executor worker dropped".to_string())?
}

/// Worker-side RUN: lazily creates the executor, then executes with the
/// deterministic input pattern the golden tests use.
fn cmd_run(exec: &mut Option<Executor>, entry: &str) -> Result<Json, String> {
    if exec.is_none() {
        *exec = Some(
            Executor::new(&Manifest::default_dir()).map_err(|e| e.to_string())?,
        );
    }
    let exec = exec.as_mut().unwrap();
    let spec = exec
        .manifest
        .get(entry)
        .ok_or_else(|| format!("unknown entry {entry:?}"))?
        .clone();
    let inputs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, t)| {
            (0..t.elements())
                .map(|j| ((j % (13 + i)) as f32 - 6.0) / 3.0)
                .collect()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let out = exec.run_f32(entry, &inputs).map_err(|e| e.to_string())?;
    Ok(Json::obj(vec![
        ("entry", Json::Str(entry.into())),
        ("outputs", Json::Num(out.len() as f64)),
        ("checksum", Json::Num(out.iter().map(|&v| v as f64).sum())),
        ("exec_ms", Json::Num(t0.elapsed().as_secs_f64() * 1e3)),
    ]))
}
