//! `cargo bench` — ablations over the design choices DESIGN.md calls
//! out. Each ablation flips one mechanism and reports how a headline
//! paper number moves, demonstrating that the reproduced effects hinge
//! on the modelled mechanisms rather than on tuning alone.
//!
//! 1. Launch lanes (1 / 2 / 4): the serialized command path is what
//!    shapes Fig 4's overlap and speedup.
//! 2. rocSPARSE software limitation (realized_flop_fraction 1.0 vs
//!    custom-kernel 0.5): flips Fig 11 from break-even to real speedup.
//! 3. Pipelined launches (on/off): the §7.2 harness property that lets
//!    sparse aggregate scaling exceed the stream count.
//! 4. Occupancy-fragmentation boost (on/off): Fig 9's 4:1 behaviour.

use mi300a_char::config::Config;
use mi300a_char::isa::Precision;
use mi300a_char::sim::{ConcurrencyProfile, Engine, KernelDesc, SparsityMode};
use mi300a_char::sparsity::SpeedupModel;
use mi300a_char::util::bench::Bencher;

fn main() {
    let cfg = Config::mi300a();
    let mut b = Bencher::from_env(1, 3);

    println!("== ablation 1: launch lanes (Fig 4 @4/@8 streams, FP32) ==");
    for lanes in [1usize, 2, 4] {
        let mut profile = ConcurrencyProfile::ace();
        profile.launch_lanes = lanes;
        let engine = Engine::new(&cfg, profile);
        let mut sp4 = 0.0;
        let mut sp8 = 0.0;
        b.bench(&format!("ace/lanes={lanes}"), || {
            let ks4 =
                vec![KernelDesc::gemm(512, Precision::F32).with_iters(100); 4];
            let ks8 =
                vec![KernelDesc::gemm(512, Precision::F32).with_iters(100); 8];
            sp4 = engine.speedup(&ks4, 40);
            sp8 = engine.speedup(&ks8, 40);
        });
        println!("   lanes={lanes}: speedup@4 {sp4:.2}x, @8 {sp8:.2}x (paper 1.8 / 2.8)");
    }

    println!("\n== ablation 2: rocSPARSE software limit (Fig 11 @2048^3) ==");
    for (label, frac, launch) in [
        ("rocsparse-path (paper)", 1.0, 4400.0),
        ("custom-kernel", 0.5, 0.0),
    ] {
        let mut c = cfg.clone();
        c.sparsity.realized_flop_fraction = frac;
        c.sparsity.dense_api_launch_us = launch;
        c.sparsity.sparse_pipe_eff = if frac < 1.0 { 1.0 } else { 0.87 };
        let mut speedup = 0.0;
        b.bench(&format!("sparsity/{label}"), || {
            let m = SpeedupModel::new(&c);
            speedup = m
                .isolated(
                    &KernelDesc::gemm(2048, Precision::Fp8),
                    SparsityMode::SparseLhs,
                )
                .speedup();
        });
        println!("   {label}: isolated speedup {speedup:.2}x");
    }

    println!("\n== ablation 3: pipelined launches (Fig 13 sparse scaling @4) ==");
    for pipelined in [true, false] {
        let mut profile = ConcurrencyProfile::sparsity();
        profile.pipelined_launch = pipelined;
        let engine = Engine::new(&cfg, profile);
        let sparse = KernelDesc::gemm(512, Precision::Fp8)
            .with_iters(50)
            .with_sparsity(SparsityMode::SparseLhs);
        let mut scaling = 0.0;
        b.bench(&format!("fig13/pipelined={pipelined}"), || {
            let solo = engine.run_solo(&sparse, 130).makespan_ns;
            let four = engine.run(&vec![sparse.clone(); 4], 130).makespan_ns;
            scaling = 4.0 * solo / four;
        });
        println!(
            "   pipelined={pipelined}: aggregate scaling {scaling:.2}x \
             (paper 4.5x with async enqueue)"
        );
    }

    println!("\n== ablation 4: fragmentation boost (Fig 9 @4:1) ==");
    for boost in [1.0, 5.0] {
        let mut profile = ConcurrencyProfile::fragmentation();
        profile.frag_boost = boost;
        profile.frag_penalty = if boost > 1.0 { 0.0 } else { 1.0 };
        let engine = Engine::new(&cfg, profile);
        let big = KernelDesc::gemm(2048, Precision::F32).with_iters(30);
        let small = KernelDesc::gemm(512, Precision::F32).with_iters(30);
        let mut sp_large = 0.0;
        b.bench(&format!("fig9/boost={boost}"), || {
            let solo = engine.run_solo(&big, 90).streams[0].total_ns();
            let pair = engine.run(&[big.clone(), small.clone()], 92);
            sp_large = solo / pair.streams[0].total_ns();
        });
        println!(
            "   boost={boost}: large-kernel speedup {sp_large:.2}x \
             (paper up to 2.4x)"
        );
    }

    println!("\n{}", b.markdown());
    match b.write_json("ablations", vec![]) {
        Ok(path) => println!("baseline written: {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_ablations.json: {e}"),
    }
}
