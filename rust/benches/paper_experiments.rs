//! `cargo bench` — end-to-end benches, one per paper table/figure.
//!
//! Each bench regenerates its artifact through the same driver as
//! `mi300a-char repro <id>` and reports the regeneration cost; the
//! rows/series themselves are printed once at the end (markdown summary)
//! so a bench run doubles as a full reproduction pass.

use mi300a_char::config::Config;
use mi300a_char::experiments::REGISTRY;
use mi300a_char::util::bench::Bencher;

fn main() {
    let cfg = Config::mi300a();
    let mut b = Bencher::from_env(1, 5);
    println!("== paper experiment regeneration (one bench per table/figure) ==");
    for spec in REGISTRY {
        b.bench(&format!("repro/{}", spec.id), || {
            let r = (spec.runner)(&cfg);
            Bencher::black_box(r.render().len());
        });
    }
    println!("\n{}", b.markdown());
    match b.write_json("paper_experiments", vec![]) {
        Ok(path) => println!("baseline written: {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_paper_experiments.json: {e}"),
    }
}
