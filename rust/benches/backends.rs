//! `cargo bench` — per-backend execution rates (DESIGN.md §6.8,
//! `BENCH_backends.json` is the machine-readable baseline; PERF.md
//! documents the schema).
//!
//! The analytic backend exists to answer scenario points ~orders of
//! magnitude faster than the DES replay. This target measures both
//! sides of that claim on the same point (the §6.1 512³ FP8 4-stream
//! workload) and on a cookbook-sized sweep:
//!
//! * `des` sim point — wall time per point plus exact DES events/sec
//!   (the engine reports its processed event count; one point costs
//!   one concurrent run + 4 solo runs for the serial baseline).
//! * `analytic` sim point — wall time per point (zero events).
//! * an 8-point stream sweep per backend (des, analytic, and the auto
//!   router), points/sec.
//!
//! `extra` carries `des_events_per_point`, `des_events_per_sec`,
//! `des_points_per_sec`, `analytic_points_per_sec`,
//! `analytic_speedup_per_point` (des mean / analytic mean — the ≥100×
//! fast-path headline), plus `auto_points_per_sec` and
//! `auto_des_fraction` (what share of the cookbook sweep the trust
//! table sends to the reference engine; docs/auto_backend.md).
//!
//! The multi-APU case (docs/multi_apu.md) runs the 1→4 data-parallel
//! scaling sweep on the DES and adds `fabric_points_per_sec`,
//! `fabric_transfer_events_per_sweep` (exact, stepped directly through
//! `sim::fabric`), and `fabric_transfer_events_per_sec`.
//!
//! The trace-replay case (docs/replay.md, DESIGN.md §6.12) sweeps a
//! 64-launch recorded timeline across three what-if transforms on the
//! DES and adds `trace_points_per_sec` and `trace_launches_per_sec`.
//!
//! Smoke mode: `MI300A_BENCH_WARMUP=1 MI300A_BENCH_ITERS=1 cargo bench`
//! (scripts/ci.sh) keeps the target compiling and running cheaply.

use mi300a_char::api::{ScenarioSpec, Shape};
use mi300a_char::backend::{self, BackendId};
use mi300a_char::config::Config;
use mi300a_char::fabric::{DeviceSet, Fabric};
use mi300a_char::isa::Precision;
use mi300a_char::sim::{ConcurrencyProfile, Engine, FabricSim};
use mi300a_char::util::bench::Bencher;
use mi300a_char::util::json::Json;

fn main() {
    let cfg = Config::mi300a();
    let mut b = Bencher::from_env(2, 10);
    let mut extra: Vec<(&str, Json)> = Vec::new();

    let des = backend::get(BackendId::Des);
    let analytic = backend::get(BackendId::Analytic);

    // The §6.1 anchor point: 512^3 FP8 across 4 streams.
    let spec = ScenarioSpec::sim(512, Precision::Fp8, 4);
    let p = spec.expand()[0];

    // Exact event count for one des point: the concurrent run plus one
    // solo run per stream (the serial-makespan baseline).
    let engine = Engine::new(&cfg, ConcurrencyProfile::ace());
    let ks = spec.kernels(&p);
    let mut events = engine.run(&ks, cfg.seed).events as f64;
    for (i, k) in ks.iter().enumerate() {
        events +=
            engine.run_solo(k, cfg.seed.wrapping_add(i as u64)).events as f64;
    }

    let rd = b.bench("sim_point/des", || {
        Bencher::black_box(des.simulate(&cfg, &spec, &p).makespan_ms);
    });
    let ra = b.bench("sim_point/analytic", || {
        Bencher::black_box(analytic.simulate(&cfg, &spec, &p).makespan_ms);
    });
    let per_point_speedup = rd.mean_ns / ra.mean_ns.max(1e-9);
    println!(
        "  -> des: {events:.0} events/point, ~{:.0} events/sec; analytic \
         {per_point_speedup:.0}x faster per point",
        rd.units_per_sec(events)
    );
    extra.push(("des_events_per_point", Json::Num(events)));
    extra.push(("des_events_per_sec", Json::Num(rd.units_per_sec(events))));
    extra.push(("des_points_per_sec", Json::Num(rd.throughput_per_sec())));
    extra.push((
        "analytic_points_per_sec",
        Json::Num(ra.throughput_per_sec()),
    ));
    extra.push((
        "analytic_speedup_per_point",
        Json::Num(per_point_speedup),
    ));

    // A cookbook-sized sweep (docs/scenarios.md #1: the occupancy
    // threshold) through each backend, points/sec.
    let mut sweep = ScenarioSpec::sim(512, Precision::Fp8, 4);
    sweep.sweep.streams = vec![1, 2, 3, 4, 6, 8, 12, 16];
    let points = sweep.expand();
    let rs = b.bench("sweep/8pts_des", || {
        for q in &points {
            Bencher::black_box(des.simulate(&cfg, &sweep, q).makespan_ms);
        }
    });
    let rsa = b.bench("sweep/8pts_analytic", || {
        for q in &points {
            Bencher::black_box(
                analytic.simulate(&cfg, &sweep, q).makespan_ms,
            );
        }
    });
    println!(
        "  -> sweep: des {:.1} points/sec, analytic {:.0} points/sec",
        rs.units_per_sec(points.len() as f64),
        rsa.units_per_sec(points.len() as f64)
    );
    extra.push((
        "sweep_des_points_per_sec",
        Json::Num(rs.units_per_sec(points.len() as f64)),
    ));
    extra.push((
        "sweep_analytic_points_per_sec",
        Json::Num(rsa.units_per_sec(points.len() as f64)),
    ));

    // The same sweep through the auto router: most points stay on the
    // analytic fast path, the out-of-trust-region tail (streams > 8)
    // falls back to the DES, so the rate lands between the two
    // concrete backends. `auto_des_fraction` records the split.
    let auto = backend::get(BackendId::Auto);
    let des_routed = points
        .iter()
        .filter(|q| {
            mi300a_char::backend::auto::TrustTable::route(&sweep, q)
                == BackendId::Des
        })
        .count();
    let rauto = b.bench("sweep/8pts_auto", || {
        for q in &points {
            Bencher::black_box(auto.simulate(&cfg, &sweep, q).makespan_ms);
        }
    });
    println!(
        "  -> sweep: auto {:.1} points/sec ({des_routed}/{} routed to des)",
        rauto.units_per_sec(points.len() as f64),
        points.len()
    );
    extra.push((
        "auto_points_per_sec",
        Json::Num(rauto.units_per_sec(points.len() as f64)),
    ));
    extra.push((
        "auto_des_fraction",
        Json::Num(des_routed as f64 / points.len() as f64),
    ));

    // Multi-APU (docs/multi_apu.md, recipe 5): the 1→4 data-parallel
    // scaling sweep on the DES. The fabric transfer-event count per
    // sweep pass is exact — stepped directly through `sim::fabric` on
    // the same schedules the backend composes.
    let mut fab = ScenarioSpec::sim(512, Precision::Fp8, 4);
    fab.shape = Shape::DataParallel;
    fab.sweep.devices = vec![1, 2, 3, 4];
    let fab_points = fab.expand();
    let mut transfer_events = 0.0;
    for q in &fab_points {
        if q.devices > 1 {
            let fabric = Fabric::for_set(DeviceSet::normalized(
                q.devices,
                fab.device_set.topology,
            ));
            let bytes =
                Fabric::shape_bytes(fab.shape, q.n, q.precision.bytes());
            let sched = fabric.shape_schedule(fab.shape, bytes);
            transfer_events +=
                FabricSim::new(fabric).run_schedule(&sched).events as f64;
        }
    }
    let rf = b.bench("sweep/4apu_data_parallel_des", || {
        for q in &fab_points {
            Bencher::black_box(des.simulate(&cfg, &fab, q).makespan_ms);
        }
    });
    println!(
        "  -> multi-APU: {:.1} points/sec, {transfer_events:.0} transfer \
         events/sweep (~{:.0} transfer events/sec)",
        rf.units_per_sec(fab_points.len() as f64),
        rf.units_per_sec(transfer_events)
    );
    extra.push((
        "fabric_points_per_sec",
        Json::Num(rf.units_per_sec(fab_points.len() as f64)),
    ));
    extra.push((
        "fabric_transfer_events_per_sweep",
        Json::Num(transfer_events),
    ));
    extra.push((
        "fabric_transfer_events_per_sec",
        Json::Num(rf.units_per_sec(transfer_events)),
    ));

    // Trace replay (docs/replay.md, recipe 7): a 64-launch fp16
    // timeline over 4 streams (every fourth launch data-sparse SpMM),
    // swept across three what-if transforms — the replay engine's
    // per-point rate on a realistic what-if comparison.
    use mi300a_char::replay::{TraceRecord, Transform};
    use mi300a_char::sim::kernel::KernelClass;
    use mi300a_char::sim::SparsityMode;
    let records: Vec<TraceRecord> = (0..64)
        .map(|i| TraceRecord {
            kernel: if i % 4 == 2 {
                KernelClass::Spmm
            } else {
                KernelClass::Gemm
            },
            n: [256, 512, 1024][i % 3],
            precision: Precision::F16,
            sparsity: SparsityMode::Dense,
            stream: i % 4,
            issue_ns: (i as u64 / 4) * 150_000,
        })
        .collect();
    let mut trace = ScenarioSpec::trace_replay(records).unwrap();
    trace.sweep.transform = vec![
        Transform::Identity,
        Transform::PrecisionRewrite(Precision::Fp8),
        Transform::SparsityEnable,
    ];
    let tpoints = trace.expand();
    let rt = b.bench("trace/64launch_3transform_des", || {
        for q in &tpoints {
            Bencher::black_box(des.simulate(&cfg, &trace, q).makespan_ms);
        }
    });
    let launches = (tpoints.len() * 64) as f64;
    println!(
        "  -> trace replay: {:.1} points/sec (~{:.0} launches/sec)",
        rt.units_per_sec(tpoints.len() as f64),
        rt.units_per_sec(launches)
    );
    extra.push((
        "trace_points_per_sec",
        Json::Num(rt.units_per_sec(tpoints.len() as f64)),
    ));
    extra.push((
        "trace_launches_per_sec",
        Json::Num(rt.units_per_sec(launches)),
    ));

    println!("\n{}", b.markdown());
    match b.write_json("backends", extra) {
        Ok(path) => println!("baseline written: {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_backends.json: {e}"),
    }
}
