//! `cargo bench` — hot-path micro-benchmarks for the §Perf pass
//! (EXPERIMENTS.md §Perf records before/after):
//!
//! * DES event loop (events/sec at 8 streams)
//! * L2 cache simulator (accesses/sec)
//! * metrics (fairness/overlap over large samples)
//! * coordinator routing (decisions/sec)
//! * 2:4 encode/decode throughput

use mi300a_char::config::Config;
use mi300a_char::coordinator::Router;
use mi300a_char::hw::CacheSim;
use mi300a_char::isa::Precision;
use mi300a_char::metrics::{fairness, overlap_efficiency};
use mi300a_char::sim::{ConcurrencyProfile, Engine, KernelDesc};
use mi300a_char::sparsity::{compress_2_4, decompress_2_4, prune_2_4};
use mi300a_char::util::bench::Bencher;

fn main() {
    let cfg = Config::mi300a();
    let mut b = Bencher::new(2, 10);

    // DES: 8 streams x 100 iterations (the Fig-4/5 workload).
    let engine = Engine::new(&cfg, ConcurrencyProfile::ace());
    let ks8 = vec![KernelDesc::gemm(512, Precision::F32).with_iters(100); 8];
    let r = b.bench("des/8streams_100iters", || {
        Bencher::black_box(engine.run(&ks8, 7).makespan_ns);
    });
    let events = 8.0 * 100.0 * 2.0;
    println!(
        "  -> ~{:.0} events/sec",
        events / (r.mean_ns / 1e9)
    );

    // DES: fragmentation pair (Fig 9).
    let pair = vec![
        KernelDesc::gemm(2048, Precision::F32).with_iters(30),
        KernelDesc::gemm(512, Precision::F32).with_iters(30),
    ];
    let engine_frag = Engine::new(&cfg, ConcurrencyProfile::fragmentation());
    b.bench("des/fig9_pair", || {
        Bencher::black_box(engine_frag.run(&pair, 9).makespan_ns);
    });

    // L2 cache simulator.
    let mut cache = CacheSim::new(4 * 1024 * 1024, 16);
    let mut addr = 0u64;
    let r = b.bench("l2/cache_sim_100k_accesses", || {
        for _ in 0..100_000 {
            addr = addr.wrapping_mul(6364136223846793005).wrapping_add(1);
            Bencher::black_box(cache.access(addr % (64 << 20), 0));
        }
    });
    println!(
        "  -> ~{:.1} M accesses/sec",
        100_000.0 / (r.mean_ns / 1e9) / 1e6
    );

    // Metrics over large samples.
    let samples: Vec<f64> = (0..10_000).map(|i| 1.0 + (i % 97) as f64).collect();
    b.bench("metrics/fairness_10k", || {
        Bencher::black_box(fairness(&samples));
    });
    let intervals: Vec<(f64, f64)> = (0..10_000)
        .map(|i| (i as f64, i as f64 + 500.0))
        .collect();
    b.bench("metrics/overlap_10k_intervals", || {
        Bencher::black_box(overlap_efficiency(&intervals));
    });

    // Router throughput.
    let r = b.bench("coordinator/route_100k", || {
        let mut router = Router::new(8, 8, 4);
        let mut id = 0u64;
        for _ in 0..100_000 {
            if let Some(d) = router.submit(id) {
                Bencher::black_box(d.ace);
                router.complete(d.stream);
            }
            id += 1;
        }
    });
    println!(
        "  -> ~{:.2} M routing decisions/sec",
        100_000.0 / (r.mean_ns / 1e9) / 1e6
    );

    // 2:4 encode/decode.
    let mat: Vec<f32> = (0..512 * 512)
        .map(|i| ((i * 2654435761usize % 1000) as f32 - 500.0) / 100.0)
        .collect();
    b.bench("sparsity/prune_compress_512x512", || {
        let p = prune_2_4(&mat, 512, 512);
        let c = compress_2_4(&p, 512, 512);
        Bencher::black_box(decompress_2_4(&c).len());
    });

    println!("\n{}", b.markdown());
}
