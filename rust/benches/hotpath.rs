//! `cargo bench` — hot-path micro-benchmarks for the §Perf pass
//! (PERF.md records before/after; `BENCH_hotpath.json` is the
//! machine-readable baseline future PRs diff against):
//!
//! * DES event loop (events/sec at 8 streams)
//! * L2 cache simulator (accesses/sec)
//! * metrics (fairness/overlap over large samples)
//! * coordinator routing (decisions/sec)
//! * 2:4 encode/decode throughput
//! * parallel `repro all` sweep vs serial (wall-clock speedup)
//!
//! Smoke mode: `MI300A_BENCH_WARMUP=1 MI300A_BENCH_ITERS=1 cargo bench`
//! (scripts/ci.sh) keeps the targets compiling and running cheaply.

use mi300a_char::config::Config;
use mi300a_char::coordinator::Router;
use mi300a_char::experiments;
use mi300a_char::hw::CacheSim;
use mi300a_char::isa::Precision;
use mi300a_char::metrics::{fairness, overlap_efficiency};
use mi300a_char::sim::{ConcurrencyProfile, Engine, KernelDesc};
use mi300a_char::sparsity::{compress_2_4, decompress_2_4, prune_2_4};
use mi300a_char::util::bench::Bencher;
use mi300a_char::util::json::Json;
use mi300a_char::util::pool;

fn main() {
    let cfg = Config::mi300a();
    let mut b = Bencher::from_env(2, 10);
    let mut extra: Vec<(&str, Json)> = Vec::new();

    // DES: 8 streams x 100 iterations (the Fig-4/5 workload). The
    // engine reports its processed event count, so events/sec is exact.
    let engine = Engine::new(&cfg, ConcurrencyProfile::ace());
    let ks8 = vec![KernelDesc::gemm(512, Precision::F32).with_iters(100); 8];
    let events = engine.run(&ks8, 7).events as f64;
    let r = b.bench("des/8streams_100iters", || {
        Bencher::black_box(engine.run(&ks8, 7).makespan_ns);
    });
    let events_per_sec = events / (r.mean_ns / 1e9);
    println!("  -> {events:.0} events, ~{events_per_sec:.0} events/sec");
    extra.push(("des_8streams_events", Json::Num(events)));
    extra.push(("des_8streams_events_per_sec", Json::Num(events_per_sec)));

    // DES: fragmentation pair (Fig 9).
    let pair = vec![
        KernelDesc::gemm(2048, Precision::F32).with_iters(30),
        KernelDesc::gemm(512, Precision::F32).with_iters(30),
    ];
    let engine_frag = Engine::new(&cfg, ConcurrencyProfile::fragmentation());
    b.bench("des/fig9_pair", || {
        Bencher::black_box(engine_frag.run(&pair, 9).makespan_ns);
    });

    // Parallel experiment sweep vs serial (the `repro all` hot path).
    // run_all(cfg, 1) is truly serial end to end: the pool's worker
    // budget pins every nested driver fan-out to one thread. Each sweep
    // runs the full 16-experiment suite, so measure the ratio with few
    // iterations instead of the micro-bench counts.
    let workers = pool::default_workers();
    let (full_warmup, full_iters) = (b.warmup, b.iters);
    b.warmup = full_warmup.min(1);
    b.iters = full_iters.min(3);
    let rs = b.bench("sweep/repro_all_serial", || {
        Bencher::black_box(experiments::run_all(&cfg, 1).len());
    });
    let rp = b.bench("sweep/repro_all_parallel", || {
        Bencher::black_box(experiments::run_all(&cfg, workers).len());
    });
    b.warmup = full_warmup;
    b.iters = full_iters;
    let sweep_speedup = rs.mean_ns / rp.mean_ns;
    println!(
        "  -> repro all: serial/parallel = {sweep_speedup:.2}x on {workers} \
         workers"
    );
    extra.push(("sweep_workers", Json::Num(workers as f64)));
    extra.push(("sweep_parallel_speedup", Json::Num(sweep_speedup)));

    // L2 cache simulator.
    let mut cache = CacheSim::new(4 * 1024 * 1024, 16);
    let mut addr = 0u64;
    let r = b.bench("l2/cache_sim_100k_accesses", || {
        for _ in 0..100_000 {
            addr = addr.wrapping_mul(6364136223846793005).wrapping_add(1);
            Bencher::black_box(cache.access(addr % (64 << 20), 0));
        }
    });
    println!(
        "  -> ~{:.1} M accesses/sec",
        100_000.0 / (r.mean_ns / 1e9) / 1e6
    );

    // Metrics over large samples.
    let samples: Vec<f64> = (0..10_000).map(|i| 1.0 + (i % 97) as f64).collect();
    b.bench("metrics/fairness_10k", || {
        Bencher::black_box(fairness(&samples));
    });
    let intervals: Vec<(f64, f64)> = (0..10_000)
        .map(|i| (i as f64, i as f64 + 500.0))
        .collect();
    b.bench("metrics/overlap_10k_intervals", || {
        Bencher::black_box(overlap_efficiency(&intervals));
    });

    // Router throughput.
    let r = b.bench("coordinator/route_100k", || {
        let mut router = Router::new(8, 8, 4);
        let mut id = 0u64;
        for _ in 0..100_000 {
            if let Some(d) = router.submit(id) {
                Bencher::black_box(d.ace);
                router.complete(d.stream);
            }
            id += 1;
        }
    });
    println!(
        "  -> ~{:.2} M routing decisions/sec",
        100_000.0 / (r.mean_ns / 1e9) / 1e6
    );

    // 2:4 encode/decode.
    let mat: Vec<f32> = (0..512 * 512)
        .map(|i| ((i * 2654435761usize % 1000) as f32 - 500.0) / 100.0)
        .collect();
    b.bench("sparsity/prune_compress_512x512", || {
        let p = prune_2_4(&mat, 512, 512);
        let c = compress_2_4(&p, 512, 512);
        Bencher::black_box(decompress_2_4(&c).len());
    });

    println!("\n{}", b.markdown());
    match b.write_json("hotpath", extra) {
        Ok(path) => println!("baseline written: {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
}
